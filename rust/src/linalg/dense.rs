//! Dense row-major matrix substrate.
//!
//! All coordinator-side numerics run in f64 (the XLA artifacts compute in
//! f32; conversion happens at the runtime boundary). Matrices here are
//! small-to-tall: N×d data panels, N×K embeddings, k×k projected problems.

use crate::util::threads::{num_threads, parallel_rows_mut};
use std::fmt;

/// Row-major dense matrix.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:>10.4} ", self.at(i, j))?;
            }
            writeln!(f, "{}", if self.cols > 8 { "..." } else { "" })?;
        }
        if self.rows > 6 {
            writeln!(f, "  ...")?;
        }
        write!(f, "]")
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Reshape in place to a zero-filled rows×cols, reusing the existing
    /// allocation whenever capacity allows. This is the workspace-reuse
    /// primitive of the zero-allocation solver path: after a first sizing
    /// pass, steady-state `reset` calls never touch the heap.
    pub fn reset(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Reserve capacity for a later [`Mat::reset`] up to rows×cols without
    /// changing the current shape.
    pub fn reserve_for(&mut self, rows: usize, cols: usize) {
        let want = rows * cols;
        if self.data.capacity() < want {
            self.data.reserve(want - self.data.len());
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    #[inline(always)]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self.set(i, j, v[i]);
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// C = A · B (threaded over rows of C, ikj loop order).
    pub fn matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let mut c = Mat::zeros(m, n);
        let a = &self.data;
        let bd = &b.data;
        parallel_rows_mut(&mut c.data, n, |row0, chunk| {
            let rows_here = chunk.len() / n;
            for r in 0..rows_here {
                let i = row0 + r;
                let crow = &mut chunk[r * n..(r + 1) * n];
                for l in 0..k {
                    let aval = a[i * k + l];
                    if aval == 0.0 {
                        continue;
                    }
                    let brow = &bd[l * n..(l + 1) * n];
                    for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                        *cj += aval * *bj;
                    }
                }
            }
        });
        c
    }

    /// C = Aᵀ · B where A is self (m×k → kᵀ side), i.e. (k×m)·(m×n).
    pub fn t_matmul(&self, b: &Mat) -> Mat {
        assert_eq!(self.rows, b.rows, "t_matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.cols);
        // Accumulate per-thread partial products over row blocks of A/B.
        let nt = num_threads();
        let chunk = m.div_ceil(nt).max(1);
        let partials: Vec<Mat> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..nt {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(m);
                if lo >= hi {
                    break;
                }
                let a = &self.data;
                let bd = &b.data;
                handles.push(s.spawn(move || {
                    let mut p = Mat::zeros(k, n);
                    for i in lo..hi {
                        let arow = &a[i * k..(i + 1) * k];
                        let brow = &bd[i * n..(i + 1) * n];
                        for (l, &av) in arow.iter().enumerate() {
                            if av == 0.0 {
                                continue;
                            }
                            let prow = &mut p.data[l * n..(l + 1) * n];
                            for (pj, bj) in prow.iter_mut().zip(brow.iter()) {
                                *pj += av * *bj;
                            }
                        }
                    }
                    p
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut c = Mat::zeros(k, n);
        for p in partials {
            for (cv, pv) in c.data.iter_mut().zip(p.data.iter()) {
                *cv += *pv;
            }
        }
        c
    }

    /// C = A · Bᵀ, (m×k)·(n×k)ᵀ → m×n. Dot-product form; both row-major
    /// operands stream contiguously.
    pub fn matmul_t(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, b.rows);
        let mut c = Mat::zeros(m, n);
        let a = &self.data;
        let bd = &b.data;
        parallel_rows_mut(&mut c.data, n, |row0, chunk| {
            let rows_here = chunk.len() / n;
            for r in 0..rows_here {
                let i = row0 + r;
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut chunk[r * n..(r + 1) * n];
                for (j, cj) in crow.iter_mut().enumerate() {
                    let brow = &bd[j * k..(j + 1) * k];
                    *cj = dot(arow, brow);
                }
            }
        });
        c
    }

    /// y = A · x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|i| dot(self.row(i), x)).collect()
    }

    /// y = Aᵀ · x.
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, x.len());
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (yj, aij) in y.iter_mut().zip(self.row(i)) {
                *yj += xi * *aij;
            }
        }
        y
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += *b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(other.data.iter()).map(|(a, b)| a - b).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Normalize each row to unit L2 norm (step 4 of Algorithm 2); rows with
    /// zero norm are left as-is.
    pub fn normalize_rows(&mut self) {
        let cols = self.cols;
        parallel_rows_mut(&mut self.data, cols, |_row0, chunk| {
            for row in chunk.chunks_mut(cols) {
                let nrm = dot(row, row).sqrt();
                if nrm > 0.0 {
                    for v in row {
                        *v /= nrm;
                    }
                }
            }
        });
    }

    /// Extract a sub-block of rows [lo, hi).
    pub fn row_block(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat::from_vec(hi - lo, self.cols, self.data[lo * self.cols..hi * self.cols].to_vec())
    }

    /// Select a subset of rows by index.
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut m = Mat::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            m.row_mut(r).copy_from_slice(self.row(i));
        }
        m
    }

    /// Keep the first `k` columns.
    pub fn first_cols(&self, k: usize) -> Mat {
        assert!(k <= self.cols);
        let mut m = Mat::zeros(self.rows, k);
        for i in 0..self.rows {
            m.row_mut(i).copy_from_slice(&self.row(i)[..k]);
        }
        m
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }
}

#[inline(always)]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled: lets LLVM vectorize without relying on fast-math.
    let n = a.len();
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = n / 4;
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

#[inline(always)]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

#[inline(always)]
pub fn nrm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared Euclidean distance.
#[inline(always)]
pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// L1 (Manhattan) distance — the Laplacian kernel's metric.
#[inline(always)]
pub fn l1dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        s += (x - y).abs();
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat(rng: &mut Pcg, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(-1.0, 1.0)).collect())
    }

    fn naive_mm(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for l in 0..a.cols {
                    s += a.at(i, l) * b.at(l, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg::seed(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (17, 9, 23), (64, 32, 8)] {
            let a = randmat(&mut rng, m, k);
            let b = randmat(&mut rng, k, n);
            let c = a.matmul(&b);
            let c0 = naive_mm(&a, &b);
            assert!(c.sub(&c0).frob_norm() < 1e-10, "({m},{k},{n})");
        }
    }

    #[test]
    fn t_matmul_and_matmul_t_match() {
        let mut rng = Pcg::seed(2);
        let a = randmat(&mut rng, 40, 7);
        let b = randmat(&mut rng, 40, 11);
        let c1 = a.t_matmul(&b);
        let c0 = naive_mm(&a.transpose(), &b);
        assert!(c1.sub(&c0).frob_norm() < 1e-10);

        let d = randmat(&mut rng, 13, 7);
        let c2 = a.matmul_t(&d);
        let c3 = naive_mm(&a, &d.transpose());
        assert!(c2.sub(&c3).frob_norm() < 1e-10);
    }

    #[test]
    fn matvec_roundtrip() {
        let mut rng = Pcg::seed(3);
        let a = randmat(&mut rng, 20, 9);
        let x: Vec<f64> = (0..9).map(|_| rng.f64()).collect();
        let y = a.matvec(&x);
        let y0 = naive_mm(&a, &Mat::from_vec(9, 1, x.clone()));
        for i in 0..20 {
            assert!((y[i] - y0.at(i, 0)).abs() < 1e-12);
        }
        let z = a.t_matvec(&y);
        let z0 = naive_mm(&a.transpose(), &Mat::from_vec(20, 1, y)).col(0);
        for j in 0..9 {
            assert!((z[j] - z0[j]).abs() < 1e-10);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg::seed(4);
        let a = randmat(&mut rng, 37, 53);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn normalize_rows_unit() {
        let mut rng = Pcg::seed(5);
        let mut a = randmat(&mut rng, 10, 6);
        a.row_mut(3).fill(0.0); // zero row survives
        a.normalize_rows();
        for i in 0..10 {
            let n = nrm2(a.row(i));
            if i == 3 {
                assert_eq!(n, 0.0);
            } else {
                assert!((n - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn distances() {
        assert_eq!(sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(l1dist(&[0.0, 0.0], &[3.0, -4.0]), 7.0);
    }

    #[test]
    fn select_and_blocks() {
        let a = Mat::from_vec(4, 2, vec![0., 1., 2., 3., 4., 5., 6., 7.]);
        let s = a.select_rows(&[3, 0]);
        assert_eq!(s.data, vec![6., 7., 0., 1.]);
        let b = a.row_block(1, 3);
        assert_eq!(b.data, vec![2., 3., 4., 5.]);
        let f = a.first_cols(1);
        assert_eq!(f.data, vec![0., 2., 4., 6.]);
    }
}
