//! Thin QR factorization via modified Gram–Schmidt with reorthogonalization.
//!
//! Used to orthonormalize tall-skinny basis blocks (N×k, k ≤ ~100) inside
//! the Davidson/Lanczos solvers. MGS with one reorthogonalization pass is
//! numerically equivalent to Householder for these shapes (Giraud et al.)
//! and keeps everything row-major friendly.

use super::dense::{axpy, dot, nrm2, Mat};

/// Result of a thin QR: `q` has orthonormal columns, `r` is upper triangular,
/// `rank` counts the columns that survived the deflation threshold.
pub struct ThinQr {
    pub q: Mat,
    pub r: Mat,
    pub rank: usize,
}

/// Thin QR of `a` (m×n, m ≥ n). Near-dependent columns are replaced by zero
/// columns in `q` (and flagged through `rank`), so callers can deflate.
pub fn thin_qr(a: &Mat) -> ThinQr {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "thin_qr expects tall matrix, got {m}x{n}");
    // work on column-major copies for contiguous column ops
    let mut cols: Vec<Vec<f64>> = (0..n).map(|j| a.col(j)).collect();
    let mut r = Mat::zeros(n, n);
    let mut rank = 0usize;
    let scale = a.frob_norm().max(f64::MIN_POSITIVE);
    let tol = 1e-12 * scale;
    for j in 0..n {
        // two MGS passes against previously accepted columns
        for _pass in 0..2 {
            for i in 0..j {
                let qi = &cols[i];
                if nrm2(qi) == 0.0 {
                    continue;
                }
                let proj = dot(qi, &cols[j]);
                r.set(i, j, r.at(i, j) + proj);
                let qi_clone = qi.clone(); // avoid simultaneous borrow
                axpy(-proj, &qi_clone, &mut cols[j]);
            }
        }
        let nrm = nrm2(&cols[j]);
        if nrm <= tol {
            cols[j].iter_mut().for_each(|v| *v = 0.0);
            r.set(j, j, 0.0);
        } else {
            let inv = 1.0 / nrm;
            cols[j].iter_mut().for_each(|v| *v *= inv);
            r.set(j, j, nrm);
            rank += 1;
        }
    }
    let mut q = Mat::zeros(m, n);
    for (j, cj) in cols.iter().enumerate() {
        q.set_col(j, cj);
    }
    ThinQr { q, r, rank }
}

/// Orthonormalize the columns of `a` against the columns of `against`
/// (if given) and against each other; returns only the independent columns.
pub fn orthonormalize_against(a: &Mat, against: Option<&Mat>) -> Mat {
    let (m, n) = (a.rows, a.cols);
    let mut cols: Vec<Vec<f64>> = (0..n).map(|j| a.col(j)).collect();
    let mut kept: Vec<Vec<f64>> = Vec::new();
    let scale = a.frob_norm().max(f64::MIN_POSITIVE);
    let tol = 1e-10 * scale;
    for cj in cols.iter_mut() {
        for _pass in 0..2 {
            if let Some(v) = against {
                for i in 0..v.cols {
                    let vi = v.col(i);
                    let proj = dot(&vi, cj);
                    axpy(-proj, &vi, cj);
                }
            }
            for qk in &kept {
                let proj = dot(qk, cj);
                axpy(-proj, qk, cj);
            }
        }
        let nrm = nrm2(cj);
        if nrm > tol {
            let inv = 1.0 / nrm;
            let mut v = cj.clone();
            v.iter_mut().for_each(|x| *x *= inv);
            kept.push(v);
        }
    }
    let mut q = Mat::zeros(m, kept.len());
    for (j, cj) in kept.iter().enumerate() {
        q.set_col(j, cj);
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat(rng: &mut Pcg, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(-1.0, 1.0)).collect())
    }

    #[test]
    fn qr_reconstructs() {
        let mut rng = Pcg::seed(10);
        let a = randmat(&mut rng, 50, 8);
        let ThinQr { q, r, rank } = thin_qr(&a);
        assert_eq!(rank, 8);
        let qr = q.matmul(&r);
        assert!(qr.sub(&a).frob_norm() < 1e-10);
        // orthonormality
        let g = q.t_matmul(&q);
        assert!(g.sub(&Mat::eye(8)).frob_norm() < 1e-10);
    }

    #[test]
    fn qr_detects_rank_deficiency() {
        let mut rng = Pcg::seed(11);
        let mut a = randmat(&mut rng, 30, 5);
        let c0 = a.col(0);
        let c1 = a.col(1);
        let dep: Vec<f64> = c0.iter().zip(&c1).map(|(x, y)| 2.0 * x - y).collect();
        a.set_col(4, &dep);
        let qr = thin_qr(&a);
        assert_eq!(qr.rank, 4);
        assert_eq!(qr.r.at(4, 4), 0.0);
    }

    #[test]
    fn ortho_against_subspace() {
        let mut rng = Pcg::seed(12);
        let v = thin_qr(&randmat(&mut rng, 40, 3)).q;
        let a = randmat(&mut rng, 40, 4);
        let q = orthonormalize_against(&a, Some(&v));
        assert_eq!(q.cols, 4);
        // orthogonal to v
        let cross = v.t_matmul(&q);
        assert!(cross.frob_norm() < 1e-9);
        // orthonormal among themselves
        let g = q.t_matmul(&q);
        assert!(g.sub(&Mat::eye(4)).frob_norm() < 1e-9);
    }

    #[test]
    fn ortho_drops_dependent() {
        let mut rng = Pcg::seed(13);
        let v = thin_qr(&randmat(&mut rng, 25, 4)).q;
        // columns that live inside span(v) must vanish
        let inside = v.matmul(&randmat(&mut rng, 4, 2));
        let q = orthonormalize_against(&inside, Some(&v));
        assert_eq!(q.cols, 0);
    }
}
