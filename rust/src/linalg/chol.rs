//! Cholesky factorization and whitening for SPD kernel blocks.
//!
//! The landmark methods need K-means on rows of `C·W₁₁^{−1/2}`. Any
//! whitening `M` with `Mᵀ W₁₁ M = I` differs from `W₁₁^{−1/2}` by a right
//! orthogonal factor, which leaves all pairwise row distances (hence
//! K-means, and the left singular subspace used by SC_Nys) unchanged — so
//! the O(m³/3) Cholesky `M = L^{−T}` replaces the iterative symmetric
//! eigensolver (§Perf iteration 3: 27 s → 0.1 s at m = 512).

use super::dense::Mat;

/// Lower-triangular Cholesky factor of an SPD matrix, with adaptive
/// diagonal jitter for numerically semi-definite kernels. Returns L with
/// A + jitter·I = L·Lᵀ.
pub fn cholesky_jittered(a: &Mat) -> Mat {
    let n = a.rows;
    assert_eq!(a.rows, a.cols);
    let mean_diag: f64 = (0..n).map(|i| a.at(i, i)).sum::<f64>() / n.max(1) as f64;
    let mut jitter = 0.0f64;
    for _attempt in 0..8 {
        if let Some(l) = try_cholesky(a, jitter) {
            return l;
        }
        jitter = if jitter == 0.0 { 1e-10 * mean_diag.max(1e-300) } else { jitter * 100.0 };
    }
    panic!("cholesky failed even with jitter {jitter:.3e}");
}

fn try_cholesky(a: &Mat, jitter: f64) -> Option<Mat> {
    let n = a.rows;
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) + if i == j { jitter } else { 0.0 };
            // s -= Σ_k L[i,k]·L[j,k]
            let (li, lj) = (l.row(i), l.row(j));
            for k in 0..j {
                s -= li[k] * lj[k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l.set(i, i, s.sqrt());
            } else {
                l.set(i, j, s / l.at(j, j));
            }
        }
    }
    Some(l)
}

/// Whitening transform: X = C·L^{−T}, computed row-wise by forward
/// substitution (Lᵀ xᵢ = cᵢ ⇔ solve L y = c then … actually
/// xᵢ solves xᵢ·Lᵀ = cᵢ, i.e. L·xᵢᵀ = cᵢᵀ — forward substitution).
pub fn whiten_rows(c: &Mat, l: &Mat) -> Mat {
    let (n, m) = (c.rows, c.cols);
    assert_eq!(l.rows, m);
    let mut out = Mat::zeros(n, m);
    crate::util::threads::parallel_rows_mut(&mut out.data, m, |row0, chunk| {
        for (r, orow) in chunk.chunks_mut(m).enumerate() {
            let crow = c.row(row0 + r);
            // forward-substitute L·y = crowᵀ
            for j in 0..m {
                let mut s = crow[j];
                let lrow = l.row(j);
                for (k, ok) in orow.iter().enumerate().take(j) {
                    s -= lrow[k] * *ok;
                }
                orow[j] = s / lrow[j];
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn spd(rng: &mut Pcg, n: usize) -> Mat {
        let b = Mat::from_vec(n, n + 3, (0..n * (n + 3)).map(|_| rng.range_f64(-1.0, 1.0)).collect());
        b.matmul_t(&b)
    }

    #[test]
    fn reconstructs() {
        let mut rng = Pcg::seed(91);
        let a = spd(&mut rng, 20);
        let l = cholesky_jittered(&a);
        let rec = l.matmul_t(&l);
        assert!(rec.sub(&a).frob_norm() < 1e-8 * a.frob_norm());
        // lower triangular
        for i in 0..20 {
            for j in (i + 1)..20 {
                assert_eq!(l.at(i, j), 0.0);
            }
        }
    }

    #[test]
    fn whitening_matches_inv_sqrt_distances() {
        // rows of C·L^{-T} and C·A^{-1/2} have identical pairwise distances
        let mut rng = Pcg::seed(92);
        let a = spd(&mut rng, 10);
        let c = Mat::from_vec(15, 10, (0..150).map(|_| rng.f64()).collect());
        let l = cholesky_jittered(&a);
        let x1 = whiten_rows(&c, &l);
        let x2 = c.matmul(&crate::linalg::sym_inv_sqrt(&a, 1e-12));
        for i in 0..15 {
            for j in 0..i {
                let d1 = crate::linalg::sqdist(x1.row(i), x1.row(j));
                let d2 = crate::linalg::sqdist(x2.row(i), x2.row(j));
                assert!((d1 - d2).abs() < 1e-6 * (1.0 + d2), "({i},{j}): {d1} vs {d2}");
            }
        }
        // and the whitening property Mᵀ·A·M = I with M = L^{-T}
        let m = whiten_rows(&Mat::eye(10), &l); // I·L^{-T} = L^{-T}
        let t = m.t_matmul(&a).matmul(&m);
        assert!(t.sub(&Mat::eye(10)).frob_norm() < 1e-7, "whitening property");
    }

    #[test]
    fn jitter_handles_semidefinite() {
        // rank-deficient PSD
        let b = Mat::from_vec(4, 2, vec![1., 0., 0., 1., 1., 1., 2., -1.]);
        let a = b.matmul_t(&b); // 4x4 rank 2
        let l = cholesky_jittered(&a);
        let rec = l.matmul_t(&l);
        assert!(rec.sub(&a).frob_norm() < 1e-4 * (1.0 + a.frob_norm()));
    }
}
