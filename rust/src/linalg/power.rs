//! Workspace'd power iteration: largest-eigenvalue estimation for
//! symmetric PSD operators.
//!
//! The compressive solver needs a cheap upper bound on λ_max(S) to map
//! the spectrum of the gram operator S = Ẑ·Ẑᵀ into the Chebyshev domain
//! [-1, 1]; Davidson/Lanczos tolerance heuristics can adopt the same
//! estimate. The operator is supplied as a closure `apply(x, y)` writing
//! y = S·x so this module stays independent of the `eigen` operator
//! trait — any matrix-free S plugs in.

use super::dense::{dot, nrm2};
use crate::util::rng::Pcg;

/// Reusable buffers for [`power_lambda_max`] — provisioned on first use,
/// steady-state iterations allocate nothing.
#[derive(Default)]
pub struct PowerIterWs {
    x: Vec<f64>,
    y: Vec<f64>,
}

impl PowerIterWs {
    pub fn new() -> Self {
        PowerIterWs::default()
    }

    fn ensure(&mut self, n: usize) {
        if self.x.len() < n {
            self.x.resize(n, 0.0);
            self.y.resize(n, 0.0);
        }
    }
}

/// Estimate λ_max of a symmetric PSD operator by `iters` rounds of power
/// iteration with Rayleigh-quotient extraction, starting from a seeded
/// Gaussian vector. `apply` must write y = S·x for `x.len() == n`.
///
/// Returns the last Rayleigh quotient xᵀSx / xᵀx — a lower bound on the
/// true λ_max that converges geometrically in the spectral gap; callers
/// needing a strict upper bound (the Chebyshev domain map) should
/// inflate by a small safety factor.
pub fn power_lambda_max(
    n: usize,
    mut apply: impl FnMut(&[f64], &mut [f64]),
    iters: usize,
    seed: u64,
    ws: &mut PowerIterWs,
) -> f64 {
    assert!(n > 0, "power_lambda_max on an empty operator");
    ws.ensure(n);
    let (x, y) = (&mut ws.x[..n], &mut ws.y[..n]);
    let mut rng = Pcg::new(seed, 0x9e37);
    for v in x.iter_mut() {
        *v = rng.normal();
    }
    let mut norm = nrm2(x);
    if norm == 0.0 {
        x[0] = 1.0;
        norm = 1.0;
    }
    for v in x.iter_mut() {
        *v /= norm;
    }
    let mut lambda = 0.0;
    for _ in 0..iters.max(1) {
        apply(x, y);
        lambda = dot(x, y);
        let ny = nrm2(y);
        if ny == 0.0 {
            // x landed in the null space — S may be exactly zero on this
            // vector; the Rayleigh quotient (0) is the honest answer.
            return 0.0;
        }
        for (xi, yi) in x.iter_mut().zip(y.iter()) {
            *xi = yi / ny;
        }
    }
    lambda
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn diagonal_spectrum_is_recovered() {
        let d = [0.5, 2.0, 9.25, 4.0, 1.0];
        let mut ws = PowerIterWs::new();
        let est = power_lambda_max(
            d.len(),
            |x, y| {
                for i in 0..d.len() {
                    y[i] = d[i] * x[i];
                }
            },
            60,
            7,
            &mut ws,
        );
        assert!((est - 9.25).abs() < 1e-9, "estimate {est} vs true 9.25");
    }

    #[test]
    fn dense_gram_matches_singular_value() {
        // S = A·Aᵀ, so λ_max(S) = σ_max(A)²; check against the small SVD.
        let mut rng = Pcg::seed(31);
        let a = Mat::from_vec(40, 12, (0..480).map(|_| rng.normal()).collect());
        let true_smax = crate::linalg::svd_thin(&a).s[0];
        let mut ws = PowerIterWs::new();
        let est = power_lambda_max(
            40,
            |x, y| {
                let xm = Mat::from_vec(40, 1, x.to_vec());
                let s = a.matmul(&a.t_matmul(&xm));
                y.copy_from_slice(&s.data);
            },
            200,
            3,
            &mut ws,
        );
        assert!(
            (est - true_smax * true_smax).abs() < 1e-6 * true_smax * true_smax,
            "λ est {est} vs σ²={}",
            true_smax * true_smax
        );
    }

    #[test]
    fn estimate_never_exceeds_true_lambda_max() {
        // Rayleigh quotients are bounded by λ_max; a short run on a
        // gapless spectrum must still return something in [λ_min, λ_max].
        let d = [3.0, 3.0, 3.0, 2.9999];
        let mut ws = PowerIterWs::new();
        let est = power_lambda_max(
            d.len(),
            |x, y| {
                for i in 0..d.len() {
                    y[i] = d[i] * x[i];
                }
            },
            5,
            11,
            &mut ws,
        );
        assert!(est <= 3.0 + 1e-12 && est >= 2.9999 - 1e-12);
    }

    #[test]
    fn workspace_reuse_is_deterministic() {
        let d = [1.0, 4.0, 2.0];
        let run = |ws: &mut PowerIterWs| {
            power_lambda_max(
                3,
                |x, y| {
                    for i in 0..3 {
                        y[i] = d[i] * x[i];
                    }
                },
                25,
                99,
                ws,
            )
        };
        let mut ws = PowerIterWs::new();
        let a = run(&mut ws);
        let b = run(&mut ws); // reused buffers, same seed → same estimate
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
