//! Dense SVD for small/skinny matrices via the Gram-matrix route:
//! A = U Σ Vᵀ with AᵀA = V Σ² Vᵀ (n ≤ ~500 columns). Used by the Nyström
//! baseline, reference checks for the iterative solvers, and tiny exact-SC
//! problems in tests.

use super::dense::Mat;
use super::symeig::sym_eig;

/// Thin SVD result; singular values descending.
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub v: Mat,
}

/// Thin SVD of `a` (m×n). Computes eig of the smaller Gram matrix, so cost
/// is O(min(m,n)³ + mn·min(m,n)).
pub fn svd_thin(a: &Mat) -> Svd {
    let (m, n) = (a.rows, a.cols);
    if m >= n {
        // AᵀA = V Σ² Vᵀ; U = A V Σ⁻¹
        let g = a.t_matmul(a); // n×n
        let e = sym_eig(&g);
        // descending order
        let mut s = Vec::with_capacity(n);
        let mut v = Mat::zeros(n, n);
        for j in 0..n {
            let src = n - 1 - j;
            let lam = e.w[src].max(0.0);
            s.push(lam.sqrt());
            let col = e.v.col(src);
            v.set_col(j, &col);
        }
        let av = a.matmul(&v);
        let mut u = Mat::zeros(m, n);
        for j in 0..n {
            let sj = s[j];
            if sj > 1e-300 {
                for i in 0..m {
                    u.set(i, j, av.at(i, j) / sj);
                }
            }
        }
        Svd { u, s, v }
    } else {
        // work on the transpose and swap U/V
        let at = a.transpose();
        let Svd { u, s, v } = svd_thin(&at);
        Svd { u: v, s, v: u }
    }
}

/// Top-k left singular vectors (descending), convenience wrapper.
pub fn top_left_singular(a: &Mat, k: usize) -> (Mat, Vec<f64>) {
    let svd = svd_thin(a);
    let k = k.min(svd.s.len());
    (svd.u.first_cols(k), svd.s[..k].to_vec())
}

/// Symmetric positive-semidefinite inverse square root B = A^{-1/2} with
/// eigenvalue clamping; used by the Nyström extension W_{11}^{-1/2}.
pub fn sym_inv_sqrt(a: &Mat, eps: f64) -> Mat {
    let e = sym_eig(a);
    let n = a.rows;
    let mut scaled = Mat::zeros(n, n);
    for j in 0..n {
        let lam = e.w[j];
        let f = if lam > eps { 1.0 / lam.sqrt() } else { 0.0 };
        for i in 0..n {
            scaled.set(i, j, e.v.at(i, j) * f);
        }
    }
    scaled.matmul_t(&e.v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat(rng: &mut Pcg, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(-1.0, 1.0)).collect())
    }

    #[test]
    fn reconstructs_tall_and_wide() {
        let mut rng = Pcg::seed(31);
        for &(m, n) in &[(20usize, 5usize), (5, 20), (12, 12)] {
            let a = randmat(&mut rng, m, n);
            let Svd { u, s, v } = svd_thin(&a);
            // A ≈ U diag(s) Vᵀ
            let k = s.len();
            let mut us = u.clone();
            for j in 0..k {
                for i in 0..us.rows {
                    us.set(i, j, us.at(i, j) * s[j]);
                }
            }
            let rec = us.matmul_t(&v);
            assert!(rec.sub(&a).frob_norm() < 1e-8 * (1.0 + a.frob_norm()), "({m},{n})");
            // descending
            for j in 1..k {
                assert!(s[j] <= s[j - 1] + 1e-12);
            }
        }
    }

    #[test]
    fn singular_values_match_known() {
        // diag(3, 2) embedded in 3x2
        let a = Mat::from_vec(3, 2, vec![3.0, 0.0, 0.0, 2.0, 0.0, 0.0]);
        let svd = svd_thin(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-10);
        assert!((svd.s[1] - 2.0).abs() < 1e-10);
    }

    #[test]
    fn inv_sqrt_inverts() {
        let mut rng = Pcg::seed(32);
        let b = randmat(&mut rng, 8, 8);
        let a = b.t_matmul(&b); // SPD (generically)
        let is = sym_inv_sqrt(&a, 1e-12);
        // (A^{-1/2})ᵀ A (A^{-1/2}) ≈ I
        let t = is.t_matmul(&a).matmul(&is);
        assert!(t.sub(&Mat::eye(8)).frob_norm() < 1e-6);
    }

    #[test]
    fn top_left_orthonormal() {
        let mut rng = Pcg::seed(33);
        let a = randmat(&mut rng, 30, 10);
        let (u, s) = top_left_singular(&a, 4);
        assert_eq!(u.cols, 4);
        assert_eq!(s.len(), 4);
        let g = u.t_matmul(&u);
        assert!(g.sub(&Mat::eye(4)).frob_norm() < 1e-8);
    }
}
