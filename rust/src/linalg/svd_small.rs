//! Dense SVD for small/skinny matrices via the Gram-matrix route:
//! A = U Σ Vᵀ with AᵀA = V Σ² Vᵀ (n ≤ ~500 columns). Used by the Nyström
//! baseline, reference checks for the iterative solvers, and tiny exact-SC
//! problems in tests.

use super::dense::Mat;
use super::symeig::{sym_eig, sym_eig_into, SymEigWs};

/// Thin SVD result; singular values descending.
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f64>,
    pub v: Mat,
}

/// Reusable buffers for [`svd_thin_into`] — the per-restart-cycle small
/// SVD of the Lanczos bidiagonal projection runs on one of these with zero
/// steady-state allocations.
pub struct SmallSvdWs {
    g: Mat,
    eig: SymEigWs,
    /// Left singular vectors, m×n (valid after `svd_thin_into`).
    pub u: Mat,
    /// Singular values, descending (valid after `svd_thin_into`).
    pub s: Vec<f64>,
    /// Right singular vectors, n×n (valid after `svd_thin_into`).
    pub v: Mat,
}

impl Default for SmallSvdWs {
    fn default() -> Self {
        Self::new()
    }
}

impl SmallSvdWs {
    pub fn new() -> SmallSvdWs {
        SmallSvdWs {
            g: Mat::zeros(0, 0),
            eig: SymEigWs::new(),
            u: Mat::zeros(0, 0),
            s: Vec::new(),
            v: Mat::zeros(0, 0),
        }
    }

    /// Pre-provision for matrices up to m×n (m ≥ n).
    pub fn reserve(&mut self, m: usize, n: usize) {
        self.g.reserve_for(n, n);
        self.eig.reserve(n);
        self.u.reserve_for(m, n);
        self.v.reserve_for(n, n);
        self.s.reserve(n.saturating_sub(self.s.len()));
    }
}

/// Thin SVD of a *tall* `a` (m×n, m ≥ n) into reusable buffers: results
/// land in `ws.u` (m×n), `ws.s` (descending), `ws.v` (n×n). Same
/// Gram-matrix route as [`svd_thin`], with the small gemms hand-rolled so
/// nothing allocates once `ws` has seen the size.
pub fn svd_thin_into(a: &Mat, ws: &mut SmallSvdWs) {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "svd_thin_into expects a tall matrix, got {m}x{n}");
    // G = AᵀA (n×n, symmetric): tiny shapes — plain triple loop
    ws.g.reset(n, n);
    for i in 0..n {
        for j in i..n {
            let mut s = 0.0;
            for r in 0..m {
                s += a.at(r, i) * a.at(r, j);
            }
            ws.g.set(i, j, s);
            ws.g.set(j, i, s);
        }
    }
    sym_eig_into(&ws.g, &mut ws.eig);
    // descending σ and V
    ws.s.clear();
    ws.v.reset(n, n);
    for j in 0..n {
        let src = n - 1 - j;
        let lam = ws.eig.w[src].max(0.0);
        ws.s.push(lam.sqrt());
        for i in 0..n {
            ws.v.set(i, j, ws.eig.vecs.at(i, src));
        }
    }
    // U = A·V·Σ⁻¹ (zero columns for σ ≈ 0, matching svd_thin)
    ws.u.reset(m, n);
    for i in 0..m {
        let arow = a.row(i);
        for j in 0..n {
            let sj = ws.s[j];
            if sj > 1e-300 {
                let mut s = 0.0;
                for l in 0..n {
                    s += arow[l] * ws.v.at(l, j);
                }
                ws.u.set(i, j, s / sj);
            }
        }
    }
}

/// Thin SVD of `a` (m×n). Computes eig of the smaller Gram matrix, so cost
/// is O(min(m,n)³ + mn·min(m,n)).
pub fn svd_thin(a: &Mat) -> Svd {
    let (m, n) = (a.rows, a.cols);
    if m >= n {
        // AᵀA = V Σ² Vᵀ; U = A V Σ⁻¹
        let g = a.t_matmul(a); // n×n
        let e = sym_eig(&g);
        // descending order
        let mut s = Vec::with_capacity(n);
        let mut v = Mat::zeros(n, n);
        for j in 0..n {
            let src = n - 1 - j;
            let lam = e.w[src].max(0.0);
            s.push(lam.sqrt());
            let col = e.v.col(src);
            v.set_col(j, &col);
        }
        let av = a.matmul(&v);
        let mut u = Mat::zeros(m, n);
        for j in 0..n {
            let sj = s[j];
            if sj > 1e-300 {
                for i in 0..m {
                    u.set(i, j, av.at(i, j) / sj);
                }
            }
        }
        Svd { u, s, v }
    } else {
        // work on the transpose and swap U/V
        let at = a.transpose();
        let Svd { u, s, v } = svd_thin(&at);
        Svd { u: v, s, v: u }
    }
}

/// Top-k left singular vectors (descending), convenience wrapper.
pub fn top_left_singular(a: &Mat, k: usize) -> (Mat, Vec<f64>) {
    let svd = svd_thin(a);
    let k = k.min(svd.s.len());
    (svd.u.first_cols(k), svd.s[..k].to_vec())
}

/// Symmetric positive-semidefinite inverse square root B = A^{-1/2} with
/// eigenvalue clamping; used by the Nyström extension W_{11}^{-1/2}.
pub fn sym_inv_sqrt(a: &Mat, eps: f64) -> Mat {
    let e = sym_eig(a);
    let n = a.rows;
    let mut scaled = Mat::zeros(n, n);
    for j in 0..n {
        let lam = e.w[j];
        let f = if lam > eps { 1.0 / lam.sqrt() } else { 0.0 };
        for i in 0..n {
            scaled.set(i, j, e.v.at(i, j) * f);
        }
    }
    scaled.matmul_t(&e.v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn randmat(rng: &mut Pcg, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.range_f64(-1.0, 1.0)).collect())
    }

    #[test]
    fn reconstructs_tall_and_wide() {
        let mut rng = Pcg::seed(31);
        for &(m, n) in &[(20usize, 5usize), (5, 20), (12, 12)] {
            let a = randmat(&mut rng, m, n);
            let Svd { u, s, v } = svd_thin(&a);
            // A ≈ U diag(s) Vᵀ
            let k = s.len();
            let mut us = u.clone();
            for j in 0..k {
                for i in 0..us.rows {
                    us.set(i, j, us.at(i, j) * s[j]);
                }
            }
            let rec = us.matmul_t(&v);
            assert!(rec.sub(&a).frob_norm() < 1e-8 * (1.0 + a.frob_norm()), "({m},{n})");
            // descending
            for j in 1..k {
                assert!(s[j] <= s[j - 1] + 1e-12);
            }
        }
    }

    #[test]
    fn into_variant_matches_allocating() {
        let mut rng = Pcg::seed(34);
        let mut ws = SmallSvdWs::new();
        for &(m, n) in &[(20usize, 5usize), (12, 12), (7, 1)] {
            let a = randmat(&mut rng, m, n);
            let full = svd_thin(&a);
            svd_thin_into(&a, &mut ws);
            for j in 0..n {
                assert!((ws.s[j] - full.s[j]).abs() < 1e-10, "({m},{n}) σ_{j}");
            }
            // same subspaces: |u_into · u_full| ≈ 1 columnwise (sign-free)
            for j in 0..n {
                if full.s[j] > 1e-8 {
                    let mut d = 0.0;
                    for i in 0..m {
                        d += ws.u.at(i, j) * full.u.at(i, j);
                    }
                    assert!(d.abs() > 1.0 - 1e-8, "({m},{n}) u_{j} align {d}");
                }
            }
        }
    }

    #[test]
    fn singular_values_match_known() {
        // diag(3, 2) embedded in 3x2
        let a = Mat::from_vec(3, 2, vec![3.0, 0.0, 0.0, 2.0, 0.0, 0.0]);
        let svd = svd_thin(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-10);
        assert!((svd.s[1] - 2.0).abs() < 1e-10);
    }

    #[test]
    fn inv_sqrt_inverts() {
        let mut rng = Pcg::seed(32);
        let b = randmat(&mut rng, 8, 8);
        let a = b.t_matmul(&b); // SPD (generically)
        let is = sym_inv_sqrt(&a, 1e-12);
        // (A^{-1/2})ᵀ A (A^{-1/2}) ≈ I
        let t = is.t_matmul(&a).matmul(&is);
        assert!(t.sub(&Mat::eye(8)).frob_norm() < 1e-6);
    }

    #[test]
    fn top_left_orthonormal() {
        let mut rng = Pcg::seed(33);
        let a = randmat(&mut rng, 30, 10);
        let (u, s) = top_left_singular(&a, 4);
        assert_eq!(u.cols, 4);
        assert_eq!(s.len(), 4);
        let g = u.t_matmul(&u);
        assert!(g.sub(&Mat::eye(4)).frob_norm() < 1e-8);
    }
}
