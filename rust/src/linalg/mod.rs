//! Dense linear-algebra substrate: row-major matrices, blocked/threaded
//! products, thin QR, small symmetric eigensolver, and small SVD — the
//! building blocks under the iterative solvers and baseline methods.

pub mod chol;
pub mod dense;
pub mod power;
pub mod qr;
pub mod svd_small;
pub mod symeig;

pub use chol::{cholesky_jittered, whiten_rows};
pub use dense::{axpy, dot, l1dist, nrm2, sqdist, Mat};
pub use power::{power_lambda_max, PowerIterWs};
pub use qr::{orthonormalize_against, thin_qr, ThinQr};
pub use svd_small::{svd_thin, svd_thin_into, sym_inv_sqrt, top_left_singular, SmallSvdWs, Svd};
pub use symeig::{sym_eig, sym_eig_into, SymEig, SymEigWs};
