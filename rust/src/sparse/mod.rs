//! Sparse substrates and the implicit graph-Laplacian algebra of §3.1:
//! degrees, normalization, and Ẑ·Ẑᵀ block application — all without
//! materializing the N×N similarity matrix.
//!
//! Two substrates, one job each:
//! - [`EllRb`] — the fixed-stride RB substrate the solver hot path runs on.
//!   Exploits RB structure (exactly R non-zeros per row, all equal to one
//!   per-row value) to drop the value array and `indptr`, fold the
//!   `D^{-1/2}` normalization into a per-row scale, and drive transpose
//!   products through a precomputed column-strip layout with zero
//!   per-thread allocations, and fuses the solver's gram product
//!   Ẑ·(Ẑᵀ·B) into one strip-tiled pass ([`EllRb::gram_matmat_into`] with
//!   a reusable [`GramScratch`]) so the D×k intermediate never exists.
//!   Produced natively by [`crate::rb::rb_features`].
//! - [`Csr`] — the general compressed-sparse-row substrate, used by
//!   baselines, irregular matrices (Nyström / LSC anchors), and as the
//!   reference implementation `EllRb` is property-tested against via
//!   [`EllRb::to_csr`].
//!
//! The streaming ingestion path (`crate::stream`) adds a third view:
//! [`BlockEllRb`], a row-wise concatenation of `EllRb` blocks built one
//! chunk group at a time, whose kernels reproduce the monolithic results
//! bit for bit so the solvers (and the streamed-fit model bytes) cannot
//! tell the difference.

pub mod block;
pub mod csr;
pub mod ell;
pub mod ops;

pub use block::BlockEllRb;
pub use csr::Csr;
pub use ell::{EllRb, GramScratch};
pub use ops::{
    apply_normalized_similarity, implicit_degrees, normalize_by_degree,
    normalized_laplacian_dense,
};
