//! Sparse-matrix substrate (CSR) and the implicit graph-Laplacian algebra
//! of §3.1: degrees, normalization, and Ẑ·Ẑᵀ block application — all
//! without materializing the N×N similarity matrix.

pub mod csr;
pub mod ops;

pub use csr::Csr;
pub use ops::{
    apply_normalized_similarity, implicit_degrees, normalize_by_degree,
    normalized_laplacian_dense,
};
