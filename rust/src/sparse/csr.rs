//! Compressed Sparse Row matrix — the substrate under the RB feature matrix
//! `Z ∈ R^{N×D}` (exactly R non-zeros per row, one per grid) and all
//! eigensolver matvecs.
//!
//! Column indices are u32: D is bounded by the total number of non-empty
//! bins (≤ N·R in the worst case, tens of millions in the paper's runs).

use crate::linalg::Mat;
use crate::util::threads::{num_threads, parallel_rows_mut};

/// CSR sparse matrix with f64 values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// Row pointers, length rows+1.
    pub indptr: Vec<usize>,
    /// Column indices, length nnz, sorted within each row.
    pub indices: Vec<u32>,
    /// Values, length nnz.
    pub data: Vec<f64>,
}

impl Csr {
    /// Build from per-row (col, val) lists. Entries within a row are sorted
    /// by column; duplicate columns within a row are summed.
    pub fn from_rows(rows: usize, cols: usize, row_entries: Vec<Vec<(u32, f64)>>) -> Csr {
        assert_eq!(row_entries.len(), rows);
        let mut indptr = Vec::with_capacity(rows + 1);
        indptr.push(0usize);
        let nnz_upper: usize = row_entries.iter().map(|r| r.len()).sum();
        let mut indices = Vec::with_capacity(nnz_upper);
        let mut data = Vec::with_capacity(nnz_upper);
        for mut entries in row_entries {
            entries.sort_unstable_by_key(|e| e.0);
            let mut i = 0;
            while i < entries.len() {
                let (c, mut v) = entries[i];
                debug_assert!((c as usize) < cols, "column {c} out of bounds {cols}");
                let mut j = i + 1;
                while j < entries.len() && entries[j].0 == c {
                    v += entries[j].1;
                    j += 1;
                }
                indices.push(c);
                data.push(v);
                i = j;
            }
            indptr.push(indices.len());
        }
        Csr { rows, cols, indptr, indices, data }
    }

    /// Build from COO triplets (row, col, val); duplicates summed.
    pub fn from_triplets(rows: usize, cols: usize, trips: &[(usize, u32, f64)]) -> Csr {
        let mut per_row: Vec<Vec<(u32, f64)>> = vec![Vec::new(); rows];
        for &(r, c, v) in trips {
            per_row[r].push((c, v));
        }
        Csr::from_rows(rows, cols, per_row)
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn row_range(&self, i: usize) -> std::ops::Range<usize> {
        self.indptr[i]..self.indptr[i + 1]
    }

    /// y = A·x (parallel over row panels).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        let (indptr, indices, data) = (&self.indptr, &self.indices, &self.data);
        parallel_rows_mut(&mut y, 1, |row0, chunk| {
            for (k, yi) in chunk.iter_mut().enumerate() {
                let i = row0 + k;
                let mut s = 0.0;
                for p in indptr[i]..indptr[i + 1] {
                    s += data[p] * x[indices[p] as usize];
                }
                *yi = s;
            }
        });
        y
    }

    /// y = Aᵀ·x (parallel over row panels with per-thread accumulators).
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let nt = num_threads();
        let chunk = self.rows.div_ceil(nt).max(1);
        let partials: Vec<Vec<f64>> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..nt {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(self.rows);
                if lo >= hi {
                    break;
                }
                let (indptr, indices, data) = (&self.indptr, &self.indices, &self.data);
                let cols = self.cols;
                handles.push(s.spawn(move || {
                    let mut y = vec![0.0; cols];
                    for i in lo..hi {
                        let xi = x[i];
                        if xi == 0.0 {
                            continue;
                        }
                        for p in indptr[i]..indptr[i + 1] {
                            y[indices[p] as usize] += data[p] * xi;
                        }
                    }
                    y
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut y = vec![0.0; self.cols];
        for p in partials {
            for (yi, pi) in y.iter_mut().zip(p.iter()) {
                *yi += *pi;
            }
        }
        y
    }

    /// C = A · B where B is dense cols×k → dense rows×k (the solver's block
    /// matvec; parallel over rows).
    pub fn matmat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.cols, "matmat shape mismatch");
        let k = b.cols;
        let mut c = Mat::zeros(self.rows, k);
        let (indptr, indices, data) = (&self.indptr, &self.indices, &self.data);
        parallel_rows_mut(&mut c.data, k, |row0, chunk| {
            for (r, crow) in chunk.chunks_mut(k).enumerate() {
                let i = row0 + r;
                for p in indptr[i]..indptr[i + 1] {
                    let v = data[p];
                    let brow = b.row(indices[p] as usize);
                    for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                        *cj += v * *bj;
                    }
                }
            }
        });
        c
    }

    /// C = Aᵀ · B where B is dense rows×k → dense cols×k (parallel with
    /// per-thread accumulation; cols×k can be large, so threads accumulate
    /// into disjoint column strips only when beneficial).
    pub fn t_matmat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.rows, "t_matmat shape mismatch");
        let k = b.cols;
        let nt = num_threads();
        let chunk = self.rows.div_ceil(nt).max(1);
        let partials: Vec<Mat> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..nt {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(self.rows);
                if lo >= hi {
                    break;
                }
                let (indptr, indices, data) = (&self.indptr, &self.indices, &self.data);
                let cols = self.cols;
                handles.push(s.spawn(move || {
                    let mut c = Mat::zeros(cols, k);
                    for i in lo..hi {
                        let brow = b.row(i);
                        for p in indptr[i]..indptr[i + 1] {
                            let v = data[p];
                            let crow = c.row_mut(indices[p] as usize);
                            for (cj, bj) in crow.iter_mut().zip(brow.iter()) {
                                *cj += v * *bj;
                            }
                        }
                    }
                    c
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut c = Mat::zeros(self.cols, k);
        for p in partials {
            c.add_assign(&p);
        }
        c
    }

    /// Row sums (A·1), parallel over row panels.
    pub fn row_sums(&self) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        let (indptr, data) = (&self.indptr, &self.data);
        parallel_rows_mut(&mut y, 1, |row0, chunk| {
            for (k, yi) in chunk.iter_mut().enumerate() {
                let i = row0 + k;
                *yi = data[indptr[i]..indptr[i + 1]].iter().sum();
            }
        });
        y
    }

    /// Column sums (Aᵀ·1) — direct parallel kernel: each worker scatters
    /// its row panel's values into a private accumulator (no ones-vector
    /// allocation, no multiplies), then partials merge.
    pub fn col_sums(&self) -> Vec<f64> {
        let nt = num_threads();
        let chunk = self.rows.div_ceil(nt.max(1)).max(1);
        let partials: Vec<Vec<f64>> = std::thread::scope(|s| {
            let mut handles = Vec::new();
            for t in 0..nt {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(self.rows);
                if lo >= hi {
                    break;
                }
                let (indptr, indices, data) = (&self.indptr, &self.indices, &self.data);
                let cols = self.cols;
                handles.push(s.spawn(move || {
                    let mut y = vec![0.0; cols];
                    for p in indptr[lo]..indptr[hi] {
                        y[indices[p] as usize] += data[p];
                    }
                    y
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let mut y = vec![0.0; self.cols];
        for p in partials {
            for (yi, pi) in y.iter_mut().zip(p.iter()) {
                *yi += *pi;
            }
        }
        y
    }

    /// Scale row i by s[i] in place (the D^{-1/2} Z normalization).
    /// Parallel over contiguous nnz chunks; each worker locates its first
    /// row with one binary search and then walks `indptr` forward.
    pub fn scale_rows(&mut self, s: &[f64]) {
        assert_eq!(s.len(), self.rows);
        let indptr = &self.indptr;
        crate::util::threads::parallel_chunks_mut(&mut self.data, num_threads(), |start, chunk| {
            // last row whose range starts at or before flat position `start`
            let mut i = indptr.partition_point(|&p| p <= start) - 1;
            let mut p = start;
            let end = start + chunk.len();
            while p < end {
                let hi = indptr[i + 1].min(end);
                let si = s[i];
                for v in &mut chunk[p - start..hi - start] {
                    *v *= si;
                }
                p = hi.max(p);
                i += 1;
            }
        });
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Materialize as dense (tests / tiny problems only).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for p in self.row_range(i) {
                m.set(i, self.indices[p] as usize, self.data[p]);
            }
        }
        m
    }

    /// Gram product G = A·Aᵀ materialized densely (tests / analysis only —
    /// this is exactly the N×N matrix the paper avoids forming).
    pub fn gram_dense(&self) -> Mat {
        let dense = self.to_dense();
        dense.matmul_t(&dense)
    }

    /// Memory footprint in bytes (indices + data + indptr).
    pub fn bytes(&self) -> usize {
        self.indices.len() * 4 + self.data.len() * 8 + self.indptr.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, rows: usize, cols: usize, per_row: usize) -> Csr {
        let mut entries = Vec::with_capacity(rows);
        for _ in 0..rows {
            let mut r = Vec::with_capacity(per_row);
            for _ in 0..per_row {
                r.push((rng.below(cols) as u32, rng.range_f64(-1.0, 1.0)));
            }
            entries.push(r);
        }
        Csr::from_rows(rows, cols, entries)
    }

    #[test]
    fn from_rows_sorts_and_dedups() {
        let a = Csr::from_rows(2, 5, vec![vec![(3, 1.0), (1, 2.0), (3, 0.5)], vec![]]);
        assert_eq!(a.indices, vec![1, 3]);
        assert_eq!(a.data, vec![2.0, 1.5]);
        assert_eq!(a.indptr, vec![0, 2, 2]);
    }

    #[test]
    fn matvec_matches_dense() {
        let mut rng = Pcg::seed(41);
        let a = random_csr(&mut rng, 50, 30, 4);
        let d = a.to_dense();
        let x: Vec<f64> = (0..30).map(|_| rng.f64()).collect();
        let y = a.matvec(&x);
        let y0 = d.matvec(&x);
        for (u, v) in y.iter().zip(y0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn t_matvec_matches_dense() {
        let mut rng = Pcg::seed(42);
        let a = random_csr(&mut rng, 50, 30, 4);
        let d = a.to_dense();
        let x: Vec<f64> = (0..50).map(|_| rng.f64()).collect();
        let y = a.t_matvec(&x);
        let y0 = d.t_matvec(&x);
        for (u, v) in y.iter().zip(y0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn matmat_and_t_matmat_match_dense() {
        let mut rng = Pcg::seed(43);
        let a = random_csr(&mut rng, 40, 25, 3);
        let d = a.to_dense();
        let b = Mat::from_vec(25, 6, (0..150).map(|_| rng.f64()).collect());
        let c = a.matmat(&b);
        let c0 = d.matmul(&b);
        assert!(c.sub(&c0).frob_norm() < 1e-12);

        let b2 = Mat::from_vec(40, 5, (0..200).map(|_| rng.f64()).collect());
        let c2 = a.t_matmat(&b2);
        let c20 = d.t_matmul(&b2);
        assert!(c2.sub(&c20).frob_norm() < 1e-12);
    }

    #[test]
    fn sums_and_scaling() {
        let a = Csr::from_rows(2, 3, vec![vec![(0, 1.0), (2, 2.0)], vec![(1, 3.0)]]);
        assert_eq!(a.row_sums(), vec![3.0, 3.0]);
        assert_eq!(a.col_sums(), vec![1.0, 3.0, 2.0]);
        let mut b = a.clone();
        b.scale_rows(&[2.0, 0.5]);
        assert_eq!(b.row_sums(), vec![6.0, 1.5]);
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Pcg::seed(44);
        let a = random_csr(&mut rng, 15, 10, 2);
        let g = a.gram_dense();
        for i in 0..15 {
            assert!(g.at(i, i) >= -1e-12);
            for j in 0..15 {
                assert!((g.at(i, j) - g.at(j, i)).abs() < 1e-12);
            }
        }
    }
}
