//! Fixed-stride RB sparse substrate (`EllRb`) — the eigensolver hot path.
//!
//! The RB feature matrix Z ∈ R^{N×D} is *structurally* ELLPACK with stride
//! R: every row has exactly R non-zeros (one bin per grid) and all of them
//! share one value, `d_i^{-1/2}/√R` after degree normalization. A general
//! CSR layout pays for that structure three times over on every solver
//! iteration: an 8-byte value per nnz that is redundant with the row, an
//! `indptr` array that is redundant with the stride, and — worst — a dense
//! D×k accumulator **per thread** in `t_matmat` plus a serial reduction.
//!
//! `EllRb` stores only what the structure requires:
//! - `indices`: flat `n×R` u32 column ids, row-major (zero-copy from the
//!   phase-2 assembly in [`crate::rb::rb_features`]);
//! - `scale`: one f64 per row — the shared value. The `D^{-1/2}`
//!   normalization folds into it, so normalizing costs O(N), not O(nnz),
//!   and never touches the index arrays;
//! - a precomputed transpose layout (`col_ptr`/`row_idx`, a CSC without
//!   values) built once at construction. `t_matmat`/`t_matvec` walk it in
//!   nnz-balanced *column strips*: each worker owns a contiguous strip of
//!   output rows, so there are **zero** per-thread D×k allocations and no
//!   reduction step, and results are deterministic regardless of thread
//!   count.
//!
//! Per-nnz memory traffic for a transpose product drops from 12 B
//! (4 B index + 8 B value) + per-thread D×k zeroing under CSR to 4 B
//! (CSC row id) here; the forward product drops from 12 B to 4 B as well.
//!
//! [`EllRb::to_csr`] bridges to the general substrate for baselines, dense
//! materialization, and tests.

use super::csr::Csr;
use crate::linalg::Mat;
use crate::util::threads::{num_threads, parallel_row_ranges_mut, parallel_rows_mut};

/// Column-block width for the k-wide inner loops: keeps the output block in
/// registers/L1 while streaming rows of B, without hurting the small-k case
/// (k ≤ 64 is a single block).
const K_BLOCK: usize = 64;

/// Fixed-stride sparse RB matrix: exactly `r` non-zeros per row, all equal
/// to `scale[row]`.
#[derive(Clone, Debug, PartialEq)]
pub struct EllRb {
    pub rows: usize,
    pub cols: usize,
    /// Non-zeros per row (the paper's R, one bin per grid).
    pub r: usize,
    /// Flat n×R column indices, row-major; strictly increasing within each
    /// row (grid blocks own disjoint ascending column ranges).
    pub indices: Vec<u32>,
    /// Per-row value: 1/√R at construction, ×d_i^{-1/2} after
    /// [`EllRb::normalize_by_degree`].
    pub scale: Vec<f64>,
    /// Transpose layout, column-major: `col_ptr` has length cols+1 and
    /// `row_idx[col_ptr[c]..col_ptr[c+1]]` lists the rows with a non-zero in
    /// column c, ascending. Values are implicit (`scale[row]`), so row
    /// scaling never invalidates this layout.
    pub col_ptr: Vec<usize>,
    pub row_idx: Vec<u32>,
}

/// nnz-balanced column-strip boundaries for `nt` workers: `bounds[t]` is the
/// first column of strip t, `bounds` spans `[0, cols]`.
fn balanced_strips(col_ptr: &[usize], nt: usize) -> Vec<usize> {
    let cols = col_ptr.len() - 1;
    let nnz = *col_ptr.last().unwrap();
    let nt = nt.clamp(1, cols.max(1));
    let mut bounds = Vec::with_capacity(nt + 1);
    bounds.push(0usize);
    for t in 1..nt {
        let target = nnz * t / nt;
        let c = col_ptr.partition_point(|&x| x < target);
        bounds.push(c.clamp(*bounds.last().unwrap(), cols));
    }
    bounds.push(cols);
    bounds
}

/// Build the valueless CSC layout with a counting sort. The scatter runs in
/// parallel over balanced column strips: strip t owns the contiguous
/// `row_idx` range `[col_ptr[bounds[t]], col_ptr[bounds[t+1]])`, so each
/// worker re-scans `indices` but writes only its own slice.
///
/// Deliberate trade: each worker re-streams the whole index array
/// (sequential, prefetch-friendly — O(nnz·threads) reads) in exchange for
/// confining its *random writes* — the expensive half of a counting sort —
/// to one contiguous strip, with zero scratch memory. The alternative, a
/// row-partitioned scatter, needs a D-sized per-worker histogram to compute
/// write offsets: exactly the per-thread D-proportional allocation pattern
/// `EllRb` exists to eliminate. This is one-time construction cost,
/// amortized over every solver iteration.
fn build_transpose(rows: usize, cols: usize, r: usize, indices: &[u32]) -> (Vec<usize>, Vec<u32>) {
    let nnz = indices.len();
    let mut col_ptr = vec![0usize; cols + 1];
    for &c in indices {
        col_ptr[c as usize + 1] += 1;
    }
    for c in 0..cols {
        col_ptr[c + 1] += col_ptr[c];
    }
    let mut row_idx = vec![0u32; nnz];
    let bounds = balanced_strips(&col_ptr, num_threads());
    std::thread::scope(|s| {
        let mut rest: &mut [u32] = &mut row_idx;
        for w in bounds.windows(2) {
            let (clo, chi) = (w[0], w[1]);
            let base = col_ptr[clo];
            let take = col_ptr[chi] - base;
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            if take == 0 {
                continue;
            }
            let col_ptr = &col_ptr;
            s.spawn(move || {
                // per-column write cursors, local to this strip
                let mut cursor: Vec<usize> =
                    col_ptr[clo..chi].iter().map(|&p| p - base).collect();
                for i in 0..rows {
                    for &c in &indices[i * r..(i + 1) * r] {
                        let c = c as usize;
                        if c < clo || c >= chi {
                            continue;
                        }
                        let slot = &mut cursor[c - clo];
                        head[*slot] = i as u32;
                        *slot += 1;
                    }
                }
            });
        }
    });
    (col_ptr, row_idx)
}

impl EllRb {
    /// Build from the flat n×R index layout (exactly what phase 2 of RB
    /// generation produces) and a per-row scale. Precomputes the transpose
    /// layout — one O(nnz) pass, amortized over every solver iteration that
    /// follows.
    pub fn new(rows: usize, cols: usize, r: usize, indices: Vec<u32>, scale: Vec<f64>) -> EllRb {
        assert!(r >= 1, "need at least one non-zero per row");
        assert_eq!(indices.len(), rows * r, "indices must be flat n x R");
        assert_eq!(scale.len(), rows, "one scale per row");
        assert!(rows <= u32::MAX as usize, "row count overflows u32");
        debug_assert!(indices.iter().all(|&c| (c as usize) < cols), "column out of bounds");
        let (col_ptr, row_idx) = build_transpose(rows, cols, r, &indices);
        EllRb { rows, cols, r, indices, scale, col_ptr, row_idx }
    }

    pub fn nnz(&self) -> usize {
        self.rows * self.r
    }

    /// Column indices of row i (length R, strictly increasing).
    #[inline]
    pub fn row_indices(&self, i: usize) -> &[u32] {
        &self.indices[i * self.r..(i + 1) * self.r]
    }

    /// y = Z·x (parallel over row panels; one multiply per row).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        let (indices, scale, r) = (&self.indices, &self.scale, self.r);
        parallel_rows_mut(&mut y, 1, |row0, chunk| {
            for (k, yi) in chunk.iter_mut().enumerate() {
                let i = row0 + k;
                let mut s = 0.0;
                for &c in &indices[i * r..(i + 1) * r] {
                    s += x[c as usize];
                }
                *yi = s * scale[i];
            }
        });
        y
    }

    /// y = Zᵀ·x via the transpose layout (parallel over column strips; no
    /// per-thread D-length accumulators, no reduction).
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        if self.cols == 0 {
            return y;
        }
        let bounds = balanced_strips(&self.col_ptr, num_threads());
        let (col_ptr, row_idx, scale) = (&self.col_ptr, &self.row_idx, &self.scale);
        parallel_row_ranges_mut(&mut y, 1, &bounds, |_si, c0, chunk| {
            for (dc, yc) in chunk.iter_mut().enumerate() {
                let col = c0 + dc;
                let mut s = 0.0;
                for p in col_ptr[col]..col_ptr[col + 1] {
                    let i = row_idx[p] as usize;
                    s += scale[i] * x[i];
                }
                *yc = s;
            }
        });
        y
    }

    /// C = Z · B, B dense cols×k → rows×k (the solver's forward block
    /// matvec; parallel over rows, k-wide loops cache-blocked).
    pub fn matmat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.cols, "matmat shape mismatch");
        let k = b.cols;
        let mut c = Mat::zeros(self.rows, k);
        let (indices, scale, r) = (&self.indices, &self.scale, self.r);
        parallel_rows_mut(&mut c.data, k, |row0, chunk| {
            for (dr, crow) in chunk.chunks_mut(k).enumerate() {
                let i = row0 + dr;
                let row = &indices[i * r..(i + 1) * r];
                let mut kb = 0;
                while kb < k {
                    let ke = (kb + K_BLOCK).min(k);
                    let cblk = &mut crow[kb..ke];
                    for &col in row {
                        let brow = &b.row(col as usize)[kb..ke];
                        for (cj, bj) in cblk.iter_mut().zip(brow.iter()) {
                            *cj += *bj;
                        }
                    }
                    kb = ke;
                }
                // all R values in the row are equal: one deferred multiply
                let si = scale[i];
                for v in crow.iter_mut() {
                    *v *= si;
                }
            }
        });
        c
    }

    /// C = Zᵀ · B, B dense rows×k → cols×k. Each worker walks a contiguous,
    /// nnz-balanced column strip of the precomputed transpose layout and
    /// writes its disjoint strip of C directly — zero per-thread D×k
    /// allocations and no reduction step, the CSR path's dominant cost.
    pub fn t_matmat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.rows, "t_matmat shape mismatch");
        let k = b.cols;
        let mut c = Mat::zeros(self.cols, k);
        if self.cols == 0 {
            return c;
        }
        let bounds = balanced_strips(&self.col_ptr, num_threads());
        let (col_ptr, row_idx, scale) = (&self.col_ptr, &self.row_idx, &self.scale);
        parallel_row_ranges_mut(&mut c.data, k, &bounds, |_si, c0, chunk| {
            for (dc, crow) in chunk.chunks_mut(k).enumerate() {
                let col = c0 + dc;
                let (lo, hi) = (col_ptr[col], col_ptr[col + 1]);
                let mut kb = 0;
                while kb < k {
                    let ke = (kb + K_BLOCK).min(k);
                    let cblk = &mut crow[kb..ke];
                    for p in lo..hi {
                        let i = row_idx[p] as usize;
                        let si = scale[i];
                        let brow = &b.row(i)[kb..ke];
                        for (cj, bj) in cblk.iter_mut().zip(brow.iter()) {
                            *cj += si * *bj;
                        }
                    }
                    kb = ke;
                }
            }
        });
        c
    }

    /// Row sums Z·1 = R·scale[i] — closed form, no memory traffic.
    pub fn row_sums(&self) -> Vec<f64> {
        let r = self.r as f64;
        self.scale.iter().map(|&s| s * r).collect()
    }

    /// Column sums Zᵀ·1 (direct parallel kernel over column strips).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        if self.cols == 0 {
            return y;
        }
        let bounds = balanced_strips(&self.col_ptr, num_threads());
        let (col_ptr, row_idx, scale) = (&self.col_ptr, &self.row_idx, &self.scale);
        parallel_row_ranges_mut(&mut y, 1, &bounds, |_si, c0, chunk| {
            for (dc, yc) in chunk.iter_mut().enumerate() {
                let col = c0 + dc;
                let mut s = 0.0;
                for p in col_ptr[col]..col_ptr[col + 1] {
                    s += scale[row_idx[p] as usize];
                }
                *yc = s;
            }
        });
        y
    }

    /// Degree vector of the implicit similarity graph, d = Z·(Zᵀ·1)
    /// (Equation 6): one O(nnz) column-sum sweep over the transpose layout,
    /// then one forward matvec.
    pub fn implicit_degrees(&self) -> Vec<f64> {
        let cs = self.col_sums();
        self.matvec(&cs)
    }

    /// Fold Ẑ = D^{-1/2}·Z into the scale vector: O(N), touches no index
    /// arrays, keeps the transpose layout valid. Rows with ~zero degree are
    /// zeroed (matching [`super::ops::normalize_by_degree`]).
    pub fn normalize_by_degree(&mut self, degrees: &[f64]) {
        assert_eq!(degrees.len(), self.rows);
        for (s, &d) in self.scale.iter_mut().zip(degrees.iter()) {
            if d > 1e-300 {
                *s /= d.sqrt();
            } else {
                *s = 0.0;
            }
        }
    }

    /// Multiply row i's (single, shared) value by s[i] — the EllRb analogue
    /// of [`Csr::scale_rows`], at O(N) instead of O(nnz).
    pub fn scale_rows(&mut self, s: &[f64]) {
        assert_eq!(s.len(), self.rows);
        for (sc, &si) in self.scale.iter_mut().zip(s.iter()) {
            *sc *= si;
        }
    }

    /// Diagonal of Z·Zᵀ: row i has R equal entries, so the squared row norm
    /// is R·scale[i]² — closed form, used by the Davidson preconditioner.
    pub fn gram_diag(&self) -> Vec<f64> {
        let r = self.r as f64;
        self.scale.iter().map(|&s| r * s * s).collect()
    }

    pub fn frob_norm(&self) -> f64 {
        let r = self.r as f64;
        self.scale.iter().map(|&s| r * s * s).sum::<f64>().sqrt()
    }

    /// Bridge to the general CSR substrate (baselines, dense
    /// materialization, equivalence tests). Row indices are already sorted,
    /// so this is a direct layout expansion.
    pub fn to_csr(&self) -> Csr {
        let indptr: Vec<usize> = (0..=self.rows).map(|i| i * self.r).collect();
        let mut data = Vec::with_capacity(self.nnz());
        for &s in &self.scale {
            data.extend(std::iter::repeat(s).take(self.r));
        }
        Csr {
            rows: self.rows,
            cols: self.cols,
            indptr,
            indices: self.indices.clone(),
            data,
        }
    }

    /// Materialize as dense (tests / tiny problems only).
    pub fn to_dense(&self) -> Mat {
        self.to_csr().to_dense()
    }

    /// Gram product G = Z·Zᵀ materialized densely (tests / analysis only).
    pub fn gram_dense(&self) -> Mat {
        self.to_csr().gram_dense()
    }

    /// Memory footprint in bytes (indices + transpose layout + scale).
    pub fn bytes(&self) -> usize {
        self.indices.len() * 4
            + self.row_idx.len() * 4
            + self.col_ptr.len() * 8
            + self.scale.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    /// Random EllRb with RB structure: r disjoint ascending "grid" column
    /// blocks, one hit per block per row.
    fn random_ell(rng: &mut Pcg, rows: usize, r: usize, bins_per_grid: usize) -> EllRb {
        let cols = r * bins_per_grid;
        let mut indices = Vec::with_capacity(rows * r);
        for _ in 0..rows {
            for j in 0..r {
                indices.push((j * bins_per_grid + rng.below(bins_per_grid)) as u32);
            }
        }
        let scale: Vec<f64> = (0..rows).map(|_| rng.range_f64(0.1, 2.0)).collect();
        EllRb::new(rows, cols, r, indices, scale)
    }

    #[test]
    fn transpose_layout_is_consistent() {
        let mut rng = Pcg::seed(71);
        let a = random_ell(&mut rng, 50, 8, 5);
        assert_eq!(*a.col_ptr.last().unwrap(), a.nnz());
        // every (row, col) pair appears exactly once in the CSC view
        let mut seen = vec![0usize; a.rows * a.cols];
        for c in 0..a.cols {
            let mut prev_row = None;
            for p in a.col_ptr[c]..a.col_ptr[c + 1] {
                let i = a.row_idx[p] as usize;
                // ascending rows within a column
                if let Some(pr) = prev_row {
                    assert!(i > pr, "rows not ascending in column {c}");
                }
                prev_row = Some(i);
                seen[i * a.cols + c] += 1;
            }
        }
        for i in 0..a.rows {
            for &c in a.row_indices(i) {
                assert_eq!(seen[i * a.cols + c as usize], 1);
            }
        }
    }

    #[test]
    fn products_match_dense() {
        let mut rng = Pcg::seed(72);
        let a = random_ell(&mut rng, 40, 6, 4);
        let d = a.to_dense();
        let x: Vec<f64> = (0..a.cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let y = a.matvec(&x);
        let y0 = d.matvec(&x);
        for (u, v) in y.iter().zip(y0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
        let u: Vec<f64> = (0..a.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let t = a.t_matvec(&u);
        let t0 = d.t_matvec(&u);
        for (u, v) in t.iter().zip(t0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
        let b = Mat::from_vec(a.cols, 5, (0..a.cols * 5).map(|_| rng.f64()).collect());
        assert!(a.matmat(&b).sub(&d.matmul(&b)).frob_norm() < 1e-12);
        let b2 = Mat::from_vec(a.rows, 7, (0..a.rows * 7).map(|_| rng.f64()).collect());
        assert!(a.t_matmat(&b2).sub(&d.t_matmul(&b2)).frob_norm() < 1e-12);
    }

    #[test]
    fn wide_blocks_exercise_cache_blocking() {
        // k > K_BLOCK forces the multi-block path in matmat / t_matmat
        let mut rng = Pcg::seed(73);
        let a = random_ell(&mut rng, 20, 4, 3);
        let d = a.to_dense();
        let k = K_BLOCK + 9;
        let b = Mat::from_vec(a.cols, k, (0..a.cols * k).map(|_| rng.f64()).collect());
        assert!(a.matmat(&b).sub(&d.matmul(&b)).frob_norm() < 1e-11);
        let b2 = Mat::from_vec(a.rows, k, (0..a.rows * k).map(|_| rng.f64()).collect());
        assert!(a.t_matmat(&b2).sub(&d.t_matmul(&b2)).frob_norm() < 1e-11);
    }

    #[test]
    fn closed_form_sums_and_diag() {
        let mut rng = Pcg::seed(74);
        let a = random_ell(&mut rng, 30, 5, 4);
        let csr = a.to_csr();
        let rs = a.row_sums();
        let rs0 = csr.row_sums();
        for (u, v) in rs.iter().zip(rs0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
        let cs = a.col_sums();
        let cs0 = csr.col_sums();
        for (u, v) in cs.iter().zip(cs0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
        let g = a.gram_diag();
        for i in 0..a.rows {
            let expect = a.r as f64 * a.scale[i] * a.scale[i];
            assert!((g[i] - expect).abs() < 1e-14);
        }
        assert!((a.frob_norm() - csr.frob_norm()).abs() < 1e-10);
    }

    #[test]
    fn degree_normalization_is_scale_only() {
        let mut rng = Pcg::seed(75);
        let mut a = random_ell(&mut rng, 25, 4, 3);
        let indices_before = a.indices.clone();
        let col_ptr_before = a.col_ptr.clone();
        let d = a.implicit_degrees();
        a.normalize_by_degree(&d);
        // index arrays untouched: normalization folded into scale
        assert_eq!(a.indices, indices_before);
        assert_eq!(a.col_ptr, col_ptr_before);
        // Perron check: Ẑ(Ẑᵀ·D^{1/2}1) = D^{1/2}1
        let sqrt_d: Vec<f64> = d.iter().map(|v| v.sqrt()).collect();
        let t = a.t_matvec(&sqrt_d);
        let s = a.matvec(&t);
        for i in 0..a.rows {
            assert!((s[i] - sqrt_d[i]).abs() < 1e-8 * (1.0 + sqrt_d[i]));
        }
    }

    #[test]
    fn zero_degree_rows_are_zeroed() {
        let mut rng = Pcg::seed(76);
        let mut a = random_ell(&mut rng, 5, 2, 2);
        let mut deg = vec![1.0; 5];
        deg[2] = 0.0;
        a.normalize_by_degree(&deg);
        assert_eq!(a.scale[2], 0.0);
        assert!(a.scale.iter().enumerate().all(|(i, &s)| i == 2 || s > 0.0));
    }

    #[test]
    fn single_row_single_grid() {
        let a = EllRb::new(1, 1, 1, vec![0], vec![0.5]);
        assert_eq!(a.matvec(&[2.0]), vec![1.0]);
        assert_eq!(a.t_matvec(&[2.0]), vec![1.0]);
        assert_eq!(a.row_sums(), vec![0.5]);
        assert_eq!(a.col_sums(), vec![0.5]);
        let c = a.to_csr();
        assert_eq!(c.indptr, vec![0, 1]);
        assert_eq!(c.data, vec![0.5]);
    }

    #[test]
    fn to_csr_roundtrips_products() {
        let mut rng = Pcg::seed(77);
        let a = random_ell(&mut rng, 35, 7, 6);
        let csr = a.to_csr();
        assert_eq!(csr.nnz(), a.nnz());
        let x: Vec<f64> = (0..a.cols).map(|_| rng.f64()).collect();
        let ya = a.matvec(&x);
        let yc = csr.matvec(&x);
        for (u, v) in ya.iter().zip(yc.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }
}
