//! Fixed-stride RB sparse substrate (`EllRb`) — the eigensolver hot path.
//!
//! The RB feature matrix Z ∈ R^{N×D} is *structurally* ELLPACK with stride
//! R: every row has exactly R non-zeros (one bin per grid) and all of them
//! share one value, `d_i^{-1/2}/√R` after degree normalization. A general
//! CSR layout pays for that structure three times over on every solver
//! iteration: an 8-byte value per nnz that is redundant with the row, an
//! `indptr` array that is redundant with the stride, and — worst — a dense
//! D×k accumulator **per thread** in `t_matmat` plus a serial reduction.
//!
//! `EllRb` stores only what the structure requires:
//! - `indices`: flat `n×R` u32 column ids, row-major (zero-copy from the
//!   phase-2 assembly in [`crate::rb::rb_features`]);
//! - `scale`: one f64 per row — the shared value. The `D^{-1/2}`
//!   normalization folds into it, so normalizing costs O(N), not O(nnz),
//!   and never touches the index arrays;
//! - a precomputed transpose layout (`col_ptr`/`row_idx`, a CSC without
//!   values) built once at construction. `t_matmat`/`t_matvec` walk it in
//!   nnz-balanced *column strips*: each worker owns a contiguous strip of
//!   output rows, so there are **zero** per-thread D×k allocations and no
//!   reduction step, and results are deterministic regardless of thread
//!   count.
//!
//! Per-nnz memory traffic for a transpose product drops from 12 B
//! (4 B index + 8 B value) + per-thread D×k zeroing under CSR to 4 B
//! (CSC row id) here; the forward product drops from 12 B to 4 B as well.
//!
//! [`EllRb::to_csr`] bridges to the general substrate for baselines, dense
//! materialization, and tests.

use super::csr::Csr;
use crate::linalg::Mat;
use crate::util::threads::{num_threads, parallel_row_ranges_mut, parallel_rows_mut};
use std::sync::Barrier;

/// Column-block width for the k-wide inner loops: keeps the output block in
/// registers/L1 while streaming rows of B, without hurting the small-k case
/// (k ≤ 64 is a single block). Shared with the block-concatenated substrate
/// (`super::block`), whose kernels must mirror these loops exactly to stay
/// bit-identical.
pub(crate) const K_BLOCK: usize = 64;

/// Per-thread tile budget for the fused gram kernel, in f64 elements
/// (256 KB — L2-resident on every target we care about). A strip's scratch
/// tile is `strip_cols × k ≤ TILE_F64_BUDGET` elements, so the fused
/// product's peak per-thread scratch is `strip_len × k × 8` bytes — the
/// D×k intermediate of the two-pass path never exists.
const TILE_F64_BUDGET: usize = 32_768;

/// Reusable scratch for [`EllRb::gram_matmat_into`]: the column-strip
/// schedule plus one cache-resident tile per worker. Create once (e.g. via
/// `GramScratch::new()` inside a solver workspace) and pass to every call;
/// `prepare` rebuilds lazily only when the operator shape, the thread
/// count, or the block width outgrows what was provisioned, so steady-state
/// calls perform **zero** heap allocations.
pub struct GramScratch {
    /// Strip boundaries over columns, ascending, spanning `[0, cols]`.
    strips: Vec<usize>,
    /// Per-worker tiles, `nt × (max_strip_cols × k_cap)` f64, flat.
    tiles: Vec<f64>,
    /// Widest strip in columns (tile row count).
    max_strip_cols: usize,
    /// Block width the tiles were provisioned for (k ≤ k_cap reuses them).
    k_cap: usize,
    /// Worker count the schedule was built for.
    nt: usize,
    /// Operator identity the schedule was built for: (rows, cols, nnz)
    /// plus a sampled fingerprint of `col_ptr`, so two operators with the
    /// same shape but different column occupancy don't silently reuse a
    /// schedule nnz-balanced for the other one.
    sig: (usize, usize, usize, u64),
    /// Dense Ẑᵀ·B intermediate for substrates that cannot fuse the gram
    /// product across row blocks (`super::block::BlockEllRb`): row-wise
    /// blocking couples all blocks through S = Ẑ·Ẑᵀ, so those operators
    /// run transpose-then-forward through this reusable D×k buffer
    /// instead of the strip tiles. Capacity-backed (`Mat::reset`), so
    /// steady-state block-gram calls stay allocation-free.
    pub(crate) inter: Mat,
}

impl Default for GramScratch {
    fn default() -> Self {
        Self::new()
    }
}

impl GramScratch {
    pub fn new() -> GramScratch {
        GramScratch {
            strips: Vec::new(),
            tiles: Vec::new(),
            max_strip_cols: 0,
            k_cap: 0,
            nt: 0,
            sig: (0, 0, 0, 0),
            inter: Mat::zeros(0, 0),
        }
    }

    /// (Re)build the strip schedule and tiles for `a` and block width `k`.
    /// No-op (and allocation-free) when the existing provisioning covers it.
    pub fn prepare(&mut self, a: &EllRb, k: usize) {
        let sig = (a.rows, a.cols, a.nnz(), col_ptr_fingerprint(&a.col_ptr));
        let nt = num_threads();
        if sig == self.sig && nt == self.nt && k <= self.k_cap {
            return;
        }
        let k_cap = k.max(self.k_cap).max(1);
        let (strips, widest) = build_gram_strips(&a.col_ptr, k_cap, nt);
        self.strips = strips;
        self.max_strip_cols = widest;
        self.k_cap = k_cap;
        self.nt = nt;
        self.sig = sig;
        let stride = self.max_strip_cols * k_cap;
        self.tiles.clear();
        self.tiles.resize(nt * stride, 0.0);
    }

    /// Total scratch footprint in bytes (all workers' tiles + the schedule
    /// + any block-substrate intermediate) — the fused kernel's
    /// replacement for the two-pass D×k intermediate.
    pub fn scratch_bytes(&self) -> usize {
        self.tiles.len() * 8 + self.strips.len() * 8 + self.inter.data.len() * 8
    }

    /// Per-thread peak scratch in bytes: one strip tile.
    pub fn tile_bytes(&self) -> usize {
        self.max_strip_cols * self.k_cap * 8
    }
}

/// FNV-1a over 16 evenly-spaced `col_ptr` samples — a cheap distribution
/// fingerprint for [`GramScratch`] staleness detection (O(1), not O(D)).
fn col_ptr_fingerprint(col_ptr: &[usize]) -> u64 {
    let mut h = crate::util::fnv::Fnv64::new();
    let n = col_ptr.len(); // always >= 1
    let samples = 16usize.min(n);
    let denom = (samples - 1).max(1);
    for s in 0..samples {
        h.write_u64(col_ptr[s * (n - 1) / denom] as u64);
    }
    h.finish()
}

/// Partition `[0, cols)` into contiguous strips that are (a) narrow enough
/// that a `strip_cols × k` tile fits the per-thread budget and (b) roughly
/// nnz-balanced so the workers of one round finish together. Returns the
/// boundaries (ascending, spanning `[0, cols]`) and the widest strip.
fn build_gram_strips(col_ptr: &[usize], k: usize, nt: usize) -> (Vec<usize>, usize) {
    let cols = col_ptr.len() - 1;
    if cols == 0 {
        return (vec![0], 0);
    }
    let nnz = *col_ptr.last().unwrap();
    let col_cap = (TILE_F64_BUDGET / k.max(1)).max(1);
    let min_strips = nt.max(cols.div_ceil(col_cap)).max(1);
    let nnz_target = nnz.div_ceil(min_strips).max(1);
    let mut strips = Vec::with_capacity(min_strips + 2);
    strips.push(0usize);
    let mut widest = 0usize;
    let mut c = 0usize;
    while c < cols {
        let start = c;
        let start_nnz = col_ptr[c];
        while c < cols && c - start < col_cap && col_ptr[c + 1] - start_nnz < nnz_target {
            c += 1;
        }
        if c == start {
            // single column heavier than the nnz target still advances
            c += 1;
        }
        strips.push(c);
        widest = widest.max(c - start);
    }
    (strips, widest)
}

/// Raw base pointer to the shared tile arena, passed to every worker.
///
/// Safety protocol (upheld by `gram_matmat_into`): in phase A of a round,
/// worker t writes only its own `[t·stride, (t+1)·stride)` region; in
/// phase B all workers only *read* tiles; the two phases are separated by
/// barriers, and the next round's phase A (which overwrites tiles) is again
/// barrier-separated from the previous phase B.
#[derive(Clone, Copy)]
struct TileArena(*mut f64);
unsafe impl Send for TileArena {}
unsafe impl Sync for TileArena {}

/// Fixed-stride sparse RB matrix: exactly `r` non-zeros per row, all equal
/// to `scale[row]`.
#[derive(Clone, Debug, PartialEq)]
pub struct EllRb {
    pub rows: usize,
    pub cols: usize,
    /// Non-zeros per row (the paper's R, one bin per grid).
    pub r: usize,
    /// Flat n×R column indices, row-major; strictly increasing within each
    /// row (grid blocks own disjoint ascending column ranges).
    pub indices: Vec<u32>,
    /// Per-row value: 1/√R at construction, ×d_i^{-1/2} after
    /// [`EllRb::normalize_by_degree`].
    pub scale: Vec<f64>,
    /// Transpose layout, column-major: `col_ptr` has length cols+1 and
    /// `row_idx[col_ptr[c]..col_ptr[c+1]]` lists the rows with a non-zero in
    /// column c, ascending. Values are implicit (`scale[row]`), so row
    /// scaling never invalidates this layout.
    pub col_ptr: Vec<usize>,
    pub row_idx: Vec<u32>,
    /// nnz-balanced column-strip boundaries for the transpose kernels
    /// (`t_matvec_into` / `t_matmat` / `col_sums`), precomputed once at
    /// construction so per-call paths stay allocation-free. Thread count is
    /// process-stable (see `util::threads::num_threads`), so these never go
    /// stale.
    pub t_bounds: Vec<usize>,
}

/// nnz-balanced column-strip boundaries for `nt` workers: `bounds[t]` is the
/// first column of strip t, `bounds` spans `[0, cols]`. Also used by
/// `super::block::BlockEllRb` over its combined column occupancy.
pub(crate) fn balanced_strips(col_ptr: &[usize], nt: usize) -> Vec<usize> {
    let cols = col_ptr.len() - 1;
    let nnz = *col_ptr.last().unwrap();
    let nt = nt.clamp(1, cols.max(1));
    let mut bounds = Vec::with_capacity(nt + 1);
    bounds.push(0usize);
    for t in 1..nt {
        let target = nnz * t / nt;
        let c = col_ptr.partition_point(|&x| x < target);
        bounds.push(c.clamp(*bounds.last().unwrap(), cols));
    }
    bounds.push(cols);
    bounds
}

/// Build the valueless CSC layout with a counting sort. The scatter runs in
/// parallel over balanced column strips: strip t owns the contiguous
/// `row_idx` range `[col_ptr[bounds[t]], col_ptr[bounds[t+1]])`, so each
/// worker re-scans `indices` but writes only its own slice.
///
/// Deliberate trade: each worker re-streams the whole index array
/// (sequential, prefetch-friendly — O(nnz·threads) reads) in exchange for
/// confining its *random writes* — the expensive half of a counting sort —
/// to one contiguous strip, with zero scratch memory. The alternative, a
/// row-partitioned scatter, needs a D-sized per-worker histogram to compute
/// write offsets: exactly the per-thread D-proportional allocation pattern
/// `EllRb` exists to eliminate. This is one-time construction cost,
/// amortized over every solver iteration.
fn build_transpose(rows: usize, cols: usize, r: usize, indices: &[u32]) -> (Vec<usize>, Vec<u32>) {
    let nnz = indices.len();
    let mut col_ptr = vec![0usize; cols + 1];
    for &c in indices {
        col_ptr[c as usize + 1] += 1;
    }
    for c in 0..cols {
        col_ptr[c + 1] += col_ptr[c];
    }
    let mut row_idx = vec![0u32; nnz];
    let bounds = balanced_strips(&col_ptr, num_threads());
    std::thread::scope(|s| {
        let mut rest: &mut [u32] = &mut row_idx;
        for w in bounds.windows(2) {
            let (clo, chi) = (w[0], w[1]);
            let base = col_ptr[clo];
            let take = col_ptr[chi] - base;
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            if take == 0 {
                continue;
            }
            let col_ptr = &col_ptr;
            s.spawn(move || {
                // per-column write cursors, local to this strip
                let mut cursor: Vec<usize> =
                    col_ptr[clo..chi].iter().map(|&p| p - base).collect();
                for i in 0..rows {
                    for &c in &indices[i * r..(i + 1) * r] {
                        let c = c as usize;
                        if c < clo || c >= chi {
                            continue;
                        }
                        let slot = &mut cursor[c - clo];
                        head[*slot] = i as u32;
                        *slot += 1;
                    }
                }
            });
        }
    });
    (col_ptr, row_idx)
}

impl EllRb {
    /// Build from the flat n×R index layout (exactly what phase 2 of RB
    /// generation produces) and a per-row scale. Precomputes the transpose
    /// layout — one O(nnz) pass, amortized over every solver iteration that
    /// follows.
    pub fn new(rows: usize, cols: usize, r: usize, indices: Vec<u32>, scale: Vec<f64>) -> EllRb {
        assert!(r >= 1, "need at least one non-zero per row");
        assert_eq!(indices.len(), rows * r, "indices must be flat n x R");
        assert_eq!(scale.len(), rows, "one scale per row");
        assert!(rows <= u32::MAX as usize, "row count overflows u32");
        debug_assert!(indices.iter().all(|&c| (c as usize) < cols), "column out of bounds");
        // The fused gram kernel binary-searches each row's indices and
        // advances its strip cursor monotonically — both rely on the
        // documented strictly-increasing-within-row invariant, so catch any
        // producer that violates it at construction.
        debug_assert!(
            (0..rows).all(|i| indices[i * r..(i + 1) * r].windows(2).all(|w| w[0] < w[1])),
            "row indices must be strictly increasing"
        );
        let (col_ptr, row_idx) = build_transpose(rows, cols, r, &indices);
        let t_bounds = balanced_strips(&col_ptr, num_threads());
        EllRb { rows, cols, r, indices, scale, col_ptr, row_idx, t_bounds }
    }

    pub fn nnz(&self) -> usize {
        self.rows * self.r
    }

    /// Column indices of row i (length R, strictly increasing).
    #[inline]
    pub fn row_indices(&self, i: usize) -> &[u32] {
        &self.indices[i * self.r..(i + 1) * self.r]
    }

    /// y = Z·x (parallel over row panels; one multiply per row).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = Z·x written into a caller-provided buffer (no allocation — the
    /// solver inner loops reuse one buffer across iterations).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        let (indices, scale, r) = (&self.indices, &self.scale, self.r);
        parallel_rows_mut(y, 1, |row0, chunk| {
            for (k, yi) in chunk.iter_mut().enumerate() {
                let i = row0 + k;
                let mut s = 0.0;
                for &c in &indices[i * r..(i + 1) * r] {
                    s += x[c as usize];
                }
                *yi = s * scale[i];
            }
        });
    }

    /// y = Zᵀ·x via the transpose layout (parallel over column strips; no
    /// per-thread D-length accumulators, no reduction).
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.t_matvec_into(x, &mut y);
        y
    }

    /// y = Zᵀ·x written into a caller-provided buffer (no allocation —
    /// the strip schedule is precomputed at construction).
    pub fn t_matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        if self.cols == 0 {
            return;
        }
        let (col_ptr, row_idx, scale) = (&self.col_ptr, &self.row_idx, &self.scale);
        parallel_row_ranges_mut(y, 1, &self.t_bounds, |_si, c0, chunk| {
            for (dc, yc) in chunk.iter_mut().enumerate() {
                let col = c0 + dc;
                let mut s = 0.0;
                for p in col_ptr[col]..col_ptr[col + 1] {
                    let i = row_idx[p] as usize;
                    s += scale[i] * x[i];
                }
                *yc = s;
            }
        });
    }

    /// C = Z · B, B dense cols×k → rows×k (the solver's forward block
    /// matvec; parallel over rows, k-wide loops cache-blocked).
    pub fn matmat(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, b.cols);
        if b.cols > 0 {
            self.matmat_into_rows(b, &mut c.data);
        } else {
            assert_eq!(b.rows, self.cols, "matmat shape mismatch");
        }
        c
    }

    /// Z · B written into a caller-provided row-major slice of length
    /// rows×k, overwriting it. This is the block-substrate building block
    /// (`super::block::BlockEllRb`): each block writes its own row range
    /// of the concatenated product. Rows are independent, so the result is
    /// bit-identical however the rows are partitioned.
    pub(crate) fn matmat_into_rows(&self, b: &Mat, out: &mut [f64]) {
        assert_eq!(b.rows, self.cols, "matmat shape mismatch");
        let k = b.cols;
        assert_eq!(out.len(), self.rows * k, "output must be rows x k");
        if self.rows == 0 || k == 0 {
            return;
        }
        let (indices, scale, r) = (&self.indices, &self.scale, self.r);
        parallel_rows_mut(out, k, |row0, chunk| {
            for (dr, crow) in chunk.chunks_mut(k).enumerate() {
                let i = row0 + dr;
                let row = &indices[i * r..(i + 1) * r];
                crow.fill(0.0);
                let mut kb = 0;
                while kb < k {
                    let ke = (kb + K_BLOCK).min(k);
                    let cblk = &mut crow[kb..ke];
                    for &col in row {
                        let brow = &b.row(col as usize)[kb..ke];
                        for (cj, bj) in cblk.iter_mut().zip(brow.iter()) {
                            *cj += *bj;
                        }
                    }
                    kb = ke;
                }
                // all R values in the row are equal: one deferred multiply
                let si = scale[i];
                for v in crow.iter_mut() {
                    *v *= si;
                }
            }
        });
    }

    /// C = Zᵀ · B, B dense rows×k → cols×k. Each worker walks a contiguous,
    /// nnz-balanced column strip of the precomputed transpose layout and
    /// writes its disjoint strip of C directly — zero per-thread D×k
    /// allocations and no reduction step, the CSR path's dominant cost.
    pub fn t_matmat(&self, b: &Mat) -> Mat {
        assert_eq!(b.rows, self.rows, "t_matmat shape mismatch");
        let k = b.cols;
        let mut c = Mat::zeros(self.cols, k);
        if self.cols == 0 {
            return c;
        }
        let (col_ptr, row_idx, scale) = (&self.col_ptr, &self.row_idx, &self.scale);
        parallel_row_ranges_mut(&mut c.data, k, &self.t_bounds, |_si, c0, chunk| {
            for (dc, crow) in chunk.chunks_mut(k).enumerate() {
                let col = c0 + dc;
                let (lo, hi) = (col_ptr[col], col_ptr[col + 1]);
                let mut kb = 0;
                while kb < k {
                    let ke = (kb + K_BLOCK).min(k);
                    let cblk = &mut crow[kb..ke];
                    for p in lo..hi {
                        let i = row_idx[p] as usize;
                        let si = scale[i];
                        let brow = &b.row(i)[kb..ke];
                        for (cj, bj) in cblk.iter_mut().zip(brow.iter()) {
                            *cj += si * *bj;
                        }
                    }
                    kb = ke;
                }
            }
        });
        c
    }

    /// Fused gram product C = Ẑ·(Ẑᵀ·B) (allocating convenience wrapper;
    /// the solver hot path uses [`EllRb::gram_matmat_into`] with a reused
    /// [`GramScratch`]).
    pub fn gram_matmat(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        let mut ws = GramScratch::new();
        self.gram_matmat_into(b, &mut out, &mut ws);
        out
    }

    /// Fused strip-tiled gram product C = Ẑ·(Ẑᵀ·B), B and C both n×k —
    /// the eigensolver's S·B without the D×k intermediate of the two-pass
    /// `matmat(t_matmat(b))` path.
    ///
    /// Columns are partitioned into cache-sized strips (see
    /// [`GramScratch`]). Workers proceed in barrier-synchronized rounds of
    /// `nt` strips:
    /// - **phase A** — worker t computes its strip's slice of ẐᵀB into its
    ///   own tile (`strip_cols × k`, L2-resident), walking the precomputed
    ///   CSC layout;
    /// - **phase B** — worker t owns a fixed partition of *output rows* and
    ///   scatters `Ẑ·tile` contributions from all of the round's tiles into
    ///   them, locating each row's columns in the round with one binary
    ///   search into its sorted index row.
    ///
    /// Substrate bytes stream once per phase (CSC row ids in A, ELL column
    /// ids in B); the D×k product of the two-pass path is replaced by
    /// `nt` tiles of ≤ `strip_len × k × 8` bytes each, and output writes
    /// are disjoint per worker — no reduction, deterministic result.
    /// The per-row scale (shared by all R entries of a row) is applied
    /// once on read (phase A) and once in a final O(N·k) pass (phase B
    /// output), exactly mirroring `t_matmat` then `matmat`.
    pub fn gram_matmat_into(&self, b: &Mat, out: &mut Mat, ws: &mut GramScratch) {
        assert_eq!(b.rows, self.rows, "gram_matmat shape mismatch");
        let k = b.cols;
        let n = self.rows;
        // Reshape without a serial zero-fill when the shape is unchanged
        // (the steady-state case): every element of `out` is written below
        // — zeroed per-worker in the parallel path, explicitly in the
        // sequential path — so pre-zeroing the whole N×k buffer here would
        // just add a redundant serial memset to the hot path.
        if out.rows != n || out.cols != k {
            out.reset(n, k);
        }
        if n == 0 || k == 0 {
            return;
        }
        if self.cols == 0 {
            out.data.fill(0.0); // Zᵀ·B is empty ⇒ C = 0
            return;
        }
        ws.prepare(self, k);
        let strips: &[usize] = &ws.strips;
        let n_strips = strips.len() - 1;
        let tile_stride = ws.max_strip_cols * ws.k_cap;
        let nt = ws.nt.min(n_strips.max(1)).max(1);
        let (indices, col_ptr, row_idx, scale, r) =
            (&self.indices, &self.col_ptr, &self.row_idx, &self.scale, self.r);

        if nt == 1 {
            // Sequential path: one tile, one strip at a time, no barriers.
            out.data.fill(0.0);
            let tiles = &mut ws.tiles;
            for s in 0..n_strips {
                let (clo, chi) = (strips[s], strips[s + 1]);
                let tile = &mut tiles[..(chi - clo) * k];
                tile.fill(0.0);
                for c in clo..chi {
                    let trow = &mut tile[(c - clo) * k..(c - clo + 1) * k];
                    for p in col_ptr[c]..col_ptr[c + 1] {
                        let i = row_idx[p] as usize;
                        let si = scale[i];
                        for (tj, bj) in trow.iter_mut().zip(b.row(i).iter()) {
                            *tj += si * *bj;
                        }
                    }
                }
                for i in 0..n {
                    let rowidx = &indices[i * r..(i + 1) * r];
                    let start = rowidx.partition_point(|&c| (c as usize) < clo);
                    let orow = out.row_mut(i);
                    for &c in &rowidx[start..] {
                        let c = c as usize;
                        if c >= chi {
                            break;
                        }
                        let trow = &tile[(c - clo) * k..(c - clo + 1) * k];
                        for (oj, tj) in orow.iter_mut().zip(trow.iter()) {
                            *oj += *tj;
                        }
                    }
                }
            }
            for i in 0..n {
                let si = scale[i];
                for v in out.row_mut(i).iter_mut() {
                    *v *= si;
                }
            }
            return;
        }

        let n_rounds = n_strips.div_ceil(nt);
        let barrier = Barrier::new(nt);
        let arena = TileArena(ws.tiles.as_mut_ptr());
        std::thread::scope(|sc| {
            let mut rest: &mut [f64] = &mut out.data;
            let mut row_lo = 0usize;
            for t in 0..nt {
                // even row partition: worker t owns rows [row_lo, row_hi)
                let row_hi = (t + 1) * n / nt;
                let take = (row_hi - row_lo) * k;
                let (my_out, tail) = rest.split_at_mut(take);
                rest = tail;
                let barrier = &barrier;
                let my_row_lo = row_lo;
                row_lo = row_hi;
                sc.spawn(move || {
                    my_out.fill(0.0);
                    for round in 0..n_rounds {
                        let s0 = round * nt;
                        // phase A: fill my tile for strip s0 + t (if any)
                        let my_strip = s0 + t;
                        if my_strip < n_strips {
                            let (clo, chi) = (strips[my_strip], strips[my_strip + 1]);
                            // SAFETY: worker t is the only writer of its
                            // region of the arena during phase A; phase B
                            // readers are barrier-separated below.
                            let tile = unsafe {
                                std::slice::from_raw_parts_mut(
                                    arena.0.add(t * tile_stride),
                                    (chi - clo) * k,
                                )
                            };
                            tile.fill(0.0);
                            for c in clo..chi {
                                let trow = &mut tile[(c - clo) * k..(c - clo + 1) * k];
                                for p in col_ptr[c]..col_ptr[c + 1] {
                                    let i = row_idx[p] as usize;
                                    let si = scale[i];
                                    for (tj, bj) in trow.iter_mut().zip(b.row(i).iter()) {
                                        *tj += si * *bj;
                                    }
                                }
                            }
                        }
                        barrier.wait();
                        // phase B: scatter this round's tiles into my rows
                        let s_end = (s0 + nt).min(n_strips);
                        let round_lo = strips[s0];
                        let round_hi = strips[s_end];
                        if round_hi > round_lo {
                            for (di, orow) in my_out.chunks_mut(k).enumerate() {
                                let i = my_row_lo + di;
                                let rowidx = &indices[i * r..(i + 1) * r];
                                let start =
                                    rowidx.partition_point(|&c| (c as usize) < round_lo);
                                let mut sidx = s0;
                                for &c in &rowidx[start..] {
                                    let c = c as usize;
                                    if c >= round_hi {
                                        break;
                                    }
                                    while strips[sidx + 1] <= c {
                                        sidx += 1;
                                    }
                                    // SAFETY: tiles are read-only in phase B
                                    // (barrier above orders them after the
                                    // writes; barrier below orders them
                                    // before the next round's writes).
                                    let trow = unsafe {
                                        std::slice::from_raw_parts(
                                            arena
                                                .0
                                                .add((sidx - s0) * tile_stride
                                                    + (c - strips[sidx]) * k),
                                            k,
                                        )
                                    };
                                    for (oj, tj) in orow.iter_mut().zip(trow.iter()) {
                                        *oj += *tj;
                                    }
                                }
                            }
                        }
                        barrier.wait();
                    }
                    // deferred per-row scale on my (exclusively owned) rows
                    for (di, orow) in my_out.chunks_mut(k).enumerate() {
                        let si = scale[my_row_lo + di];
                        for v in orow.iter_mut() {
                            *v *= si;
                        }
                    }
                });
            }
        });
    }

    /// Row sums Z·1 = R·scale[i] — closed form, no memory traffic.
    pub fn row_sums(&self) -> Vec<f64> {
        let r = self.r as f64;
        self.scale.iter().map(|&s| s * r).collect()
    }

    /// Column sums Zᵀ·1 (direct parallel kernel over column strips).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        if self.cols == 0 {
            return y;
        }
        let (col_ptr, row_idx, scale) = (&self.col_ptr, &self.row_idx, &self.scale);
        parallel_row_ranges_mut(&mut y, 1, &self.t_bounds, |_si, c0, chunk| {
            for (dc, yc) in chunk.iter_mut().enumerate() {
                let col = c0 + dc;
                let mut s = 0.0;
                for p in col_ptr[col]..col_ptr[col + 1] {
                    s += scale[row_idx[p] as usize];
                }
                *yc = s;
            }
        });
        y
    }

    /// Degree vector of the implicit similarity graph, d = Z·(Zᵀ·1)
    /// (Equation 6): one O(nnz) column-sum sweep over the transpose layout,
    /// then one forward matvec.
    pub fn implicit_degrees(&self) -> Vec<f64> {
        let cs = self.col_sums();
        self.matvec(&cs)
    }

    /// Fold Ẑ = D^{-1/2}·Z into the scale vector: O(N), touches no index
    /// arrays, keeps the transpose layout valid. Rows with ~zero degree are
    /// zeroed (matching [`super::ops::normalize_by_degree`]).
    pub fn normalize_by_degree(&mut self, degrees: &[f64]) {
        assert_eq!(degrees.len(), self.rows);
        for (s, &d) in self.scale.iter_mut().zip(degrees.iter()) {
            if d > 1e-300 {
                *s /= d.sqrt();
            } else {
                *s = 0.0;
            }
        }
    }

    /// Multiply row i's (single, shared) value by s[i] — the EllRb analogue
    /// of [`Csr::scale_rows`], at O(N) instead of O(nnz).
    pub fn scale_rows(&mut self, s: &[f64]) {
        assert_eq!(s.len(), self.rows);
        for (sc, &si) in self.scale.iter_mut().zip(s.iter()) {
            *sc *= si;
        }
    }

    /// Diagonal of Z·Zᵀ: row i has R equal entries, so the squared row norm
    /// is R·scale[i]² — closed form, used by the Davidson preconditioner.
    pub fn gram_diag(&self) -> Vec<f64> {
        let r = self.r as f64;
        self.scale.iter().map(|&s| r * s * s).collect()
    }

    pub fn frob_norm(&self) -> f64 {
        let r = self.r as f64;
        self.scale.iter().map(|&s| r * s * s).sum::<f64>().sqrt()
    }

    /// Bridge to the general CSR substrate (baselines, dense
    /// materialization, equivalence tests). Row indices are already sorted,
    /// so this is a direct layout expansion.
    pub fn to_csr(&self) -> Csr {
        let indptr: Vec<usize> = (0..=self.rows).map(|i| i * self.r).collect();
        let mut data = Vec::with_capacity(self.nnz());
        for &s in &self.scale {
            data.extend(std::iter::repeat(s).take(self.r));
        }
        Csr {
            rows: self.rows,
            cols: self.cols,
            indptr,
            indices: self.indices.clone(),
            data,
        }
    }

    /// Materialize as dense (tests / tiny problems only).
    pub fn to_dense(&self) -> Mat {
        self.to_csr().to_dense()
    }

    /// Gram product G = Z·Zᵀ materialized densely (tests / analysis only).
    pub fn gram_dense(&self) -> Mat {
        self.to_csr().gram_dense()
    }

    /// Memory footprint in bytes (indices + transpose layout + scale).
    pub fn bytes(&self) -> usize {
        self.indices.len() * 4
            + self.row_idx.len() * 4
            + self.col_ptr.len() * 8
            + self.scale.len() * 8
            + self.t_bounds.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    /// Random EllRb with RB structure: r disjoint ascending "grid" column
    /// blocks, one hit per block per row.
    fn random_ell(rng: &mut Pcg, rows: usize, r: usize, bins_per_grid: usize) -> EllRb {
        let cols = r * bins_per_grid;
        let mut indices = Vec::with_capacity(rows * r);
        for _ in 0..rows {
            for j in 0..r {
                indices.push((j * bins_per_grid + rng.below(bins_per_grid)) as u32);
            }
        }
        let scale: Vec<f64> = (0..rows).map(|_| rng.range_f64(0.1, 2.0)).collect();
        EllRb::new(rows, cols, r, indices, scale)
    }

    #[test]
    fn transpose_layout_is_consistent() {
        let mut rng = Pcg::seed(71);
        let a = random_ell(&mut rng, 50, 8, 5);
        assert_eq!(*a.col_ptr.last().unwrap(), a.nnz());
        // every (row, col) pair appears exactly once in the CSC view
        let mut seen = vec![0usize; a.rows * a.cols];
        for c in 0..a.cols {
            let mut prev_row = None;
            for p in a.col_ptr[c]..a.col_ptr[c + 1] {
                let i = a.row_idx[p] as usize;
                // ascending rows within a column
                if let Some(pr) = prev_row {
                    assert!(i > pr, "rows not ascending in column {c}");
                }
                prev_row = Some(i);
                seen[i * a.cols + c] += 1;
            }
        }
        for i in 0..a.rows {
            for &c in a.row_indices(i) {
                assert_eq!(seen[i * a.cols + c as usize], 1);
            }
        }
    }

    #[test]
    fn products_match_dense() {
        let mut rng = Pcg::seed(72);
        let a = random_ell(&mut rng, 40, 6, 4);
        let d = a.to_dense();
        let x: Vec<f64> = (0..a.cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let y = a.matvec(&x);
        let y0 = d.matvec(&x);
        for (u, v) in y.iter().zip(y0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
        let u: Vec<f64> = (0..a.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let t = a.t_matvec(&u);
        let t0 = d.t_matvec(&u);
        for (u, v) in t.iter().zip(t0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
        let b = Mat::from_vec(a.cols, 5, (0..a.cols * 5).map(|_| rng.f64()).collect());
        assert!(a.matmat(&b).sub(&d.matmul(&b)).frob_norm() < 1e-12);
        let b2 = Mat::from_vec(a.rows, 7, (0..a.rows * 7).map(|_| rng.f64()).collect());
        assert!(a.t_matmat(&b2).sub(&d.t_matmul(&b2)).frob_norm() < 1e-12);
    }

    #[test]
    fn wide_blocks_exercise_cache_blocking() {
        // k > K_BLOCK forces the multi-block path in matmat / t_matmat
        let mut rng = Pcg::seed(73);
        let a = random_ell(&mut rng, 20, 4, 3);
        let d = a.to_dense();
        let k = K_BLOCK + 9;
        let b = Mat::from_vec(a.cols, k, (0..a.cols * k).map(|_| rng.f64()).collect());
        assert!(a.matmat(&b).sub(&d.matmul(&b)).frob_norm() < 1e-11);
        let b2 = Mat::from_vec(a.rows, k, (0..a.rows * k).map(|_| rng.f64()).collect());
        assert!(a.t_matmat(&b2).sub(&d.t_matmul(&b2)).frob_norm() < 1e-11);
    }

    #[test]
    fn closed_form_sums_and_diag() {
        let mut rng = Pcg::seed(74);
        let a = random_ell(&mut rng, 30, 5, 4);
        let csr = a.to_csr();
        let rs = a.row_sums();
        let rs0 = csr.row_sums();
        for (u, v) in rs.iter().zip(rs0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
        let cs = a.col_sums();
        let cs0 = csr.col_sums();
        for (u, v) in cs.iter().zip(cs0.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
        let g = a.gram_diag();
        for i in 0..a.rows {
            let expect = a.r as f64 * a.scale[i] * a.scale[i];
            assert!((g[i] - expect).abs() < 1e-14);
        }
        assert!((a.frob_norm() - csr.frob_norm()).abs() < 1e-10);
    }

    #[test]
    fn degree_normalization_is_scale_only() {
        let mut rng = Pcg::seed(75);
        let mut a = random_ell(&mut rng, 25, 4, 3);
        let indices_before = a.indices.clone();
        let col_ptr_before = a.col_ptr.clone();
        let d = a.implicit_degrees();
        a.normalize_by_degree(&d);
        // index arrays untouched: normalization folded into scale
        assert_eq!(a.indices, indices_before);
        assert_eq!(a.col_ptr, col_ptr_before);
        // Perron check: Ẑ(Ẑᵀ·D^{1/2}1) = D^{1/2}1
        let sqrt_d: Vec<f64> = d.iter().map(|v| v.sqrt()).collect();
        let t = a.t_matvec(&sqrt_d);
        let s = a.matvec(&t);
        for i in 0..a.rows {
            assert!((s[i] - sqrt_d[i]).abs() < 1e-8 * (1.0 + sqrt_d[i]));
        }
    }

    #[test]
    fn zero_degree_rows_are_zeroed() {
        let mut rng = Pcg::seed(76);
        let mut a = random_ell(&mut rng, 5, 2, 2);
        let mut deg = vec![1.0; 5];
        deg[2] = 0.0;
        a.normalize_by_degree(&deg);
        assert_eq!(a.scale[2], 0.0);
        assert!(a.scale.iter().enumerate().all(|(i, &s)| i == 2 || s > 0.0));
    }

    #[test]
    fn single_row_single_grid() {
        let a = EllRb::new(1, 1, 1, vec![0], vec![0.5]);
        assert_eq!(a.matvec(&[2.0]), vec![1.0]);
        assert_eq!(a.t_matvec(&[2.0]), vec![1.0]);
        assert_eq!(a.row_sums(), vec![0.5]);
        assert_eq!(a.col_sums(), vec![0.5]);
        let c = a.to_csr();
        assert_eq!(c.indptr, vec![0, 1]);
        assert_eq!(c.data, vec![0.5]);
    }

    #[test]
    fn fused_gram_matches_two_pass() {
        let mut rng = Pcg::seed(78);
        for &(rows, r, bpg) in &[(40usize, 6usize, 4usize), (1, 3, 5), (13, 1, 7), (64, 8, 2)] {
            let a = random_ell(&mut rng, rows, r, bpg);
            for &k in &[1usize, 3, 8] {
                let b = Mat::from_vec(
                    a.rows,
                    k,
                    (0..a.rows * k).map(|_| rng.range_f64(-1.0, 1.0)).collect(),
                );
                let two_pass = a.matmat(&a.t_matmat(&b));
                let fused = a.gram_matmat(&b);
                assert_eq!((fused.rows, fused.cols), (a.rows, k));
                let err = fused.sub(&two_pass).frob_norm();
                assert!(
                    err < 1e-12 * (1.0 + two_pass.frob_norm()),
                    "fused vs two-pass ({rows},{r},{bpg}) k={k}: {err}"
                );
            }
        }
    }

    #[test]
    fn fused_gram_scratch_reuse_across_shapes() {
        // one GramScratch re-provisioned across operators and block widths
        let mut rng = Pcg::seed(79);
        let mut ws = GramScratch::new();
        let mut out = Mat::zeros(0, 0);
        for &(rows, r, bpg, k) in
            &[(30usize, 4usize, 3usize, 5usize), (50, 7, 6, 2), (30, 4, 3, 9), (8, 2, 2, 1)]
        {
            let a = random_ell(&mut rng, rows, r, bpg);
            let b = Mat::from_vec(
                a.rows,
                k,
                (0..a.rows * k).map(|_| rng.range_f64(-1.0, 1.0)).collect(),
            );
            a.gram_matmat_into(&b, &mut out, &mut ws);
            let reference = a.matmat(&a.t_matmat(&b));
            let err = out.sub(&reference).frob_norm();
            assert!(err < 1e-12 * (1.0 + reference.frob_norm()), "reuse err {err}");
            // steady state: same shape, dirty out — must fully overwrite,
            // not accumulate (the reshape skips the serial pre-zero)
            a.gram_matmat_into(&b, &mut out, &mut ws);
            let err2 = out.sub(&reference).frob_norm();
            assert!(err2 < 1e-12 * (1.0 + reference.frob_norm()), "dirty-out err {err2}");
        }
    }

    #[test]
    fn fused_gram_degenerate_shapes() {
        // empty-column-heavy operator: most columns never referenced
        let a = EllRb::new(3, 50, 2, vec![0, 40, 5, 49, 0, 40], vec![0.7, 1.3, 0.2]);
        let b = Mat::from_vec(3, 4, (0..12).map(|i| i as f64 - 5.0).collect());
        let reference = a.matmat(&a.t_matmat(&b));
        let fused = a.gram_matmat(&b);
        assert!(fused.sub(&reference).frob_norm() < 1e-12 * (1.0 + reference.frob_norm()));
        // single row, single entry
        let s = EllRb::new(1, 1, 1, vec![0], vec![0.5]);
        let b1 = Mat::from_vec(1, 2, vec![2.0, -4.0]);
        let g = s.gram_matmat(&b1);
        // S = 0.25 ⇒ C = 0.25·B
        assert!((g.at(0, 0) - 0.5).abs() < 1e-15);
        assert!((g.at(0, 1) + 1.0).abs() < 1e-15);
    }

    #[test]
    fn to_csr_roundtrips_products() {
        let mut rng = Pcg::seed(77);
        let a = random_ell(&mut rng, 35, 7, 6);
        let csr = a.to_csr();
        assert_eq!(csr.nnz(), a.nnz());
        let x: Vec<f64> = (0..a.cols).map(|_| rng.f64()).collect();
        let ya = a.matvec(&x);
        let yc = csr.matvec(&x);
        for (u, v) in ya.iter().zip(yc.iter()) {
            assert!((u - v).abs() < 1e-12);
        }
    }
}
