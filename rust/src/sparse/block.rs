//! Row-concatenated RB substrate (`BlockEllRb`) — the streaming twin of
//! [`EllRb`].
//!
//! The out-of-core ingestion path (`crate::stream`) featurizes the dataset
//! in fixed-row-count chunks and assembles each group of chunks into its
//! own [`EllRb`] block over the *full* column space D. `BlockEllRb` stacks
//! those blocks row-wise and implements every solver-visible operation —
//! including the [`crate::eigen::SvdOp`] `gram_matmat` contract — by
//! iterating blocks, so Davidson/Lanczos run on a streamed Ẑ completely
//! unchanged.
//!
//! # Bit-exactness contract
//!
//! Every kernel here reproduces the monolithic [`EllRb`] result **bit for
//! bit**, not just within tolerance: forward products are row-independent
//! (identical per-row loops), and transpose products accumulate each
//! output column across blocks *in block order* with a single running
//! accumulator — exactly the ascending-global-row order the monolithic
//! CSC walk uses, so every float is added in the same sequence. The fused
//! gram product is realized as transpose-then-forward through a reusable
//! dense D×k intermediate held in [`GramScratch`]; since the monolithic
//! fused kernel's tiles hold exactly the same partial sums in the same
//! order, the results agree bitwise (pinned by tests below). This is what
//! lets a streamed fit produce a model byte-identical to the in-memory
//! fit.
//!
//! The price of row-wise blocking is that the gram product cannot fuse
//! away the D×k intermediate (S = Ẑ·Ẑᵀ couples all row blocks), so the
//! streaming path trades the monolithic path's cache-sized tiles for one
//! reusable D×k scratch — the same traffic the pre-fusion two-pass
//! product paid, and still allocation-free in steady state.

use super::csr::Csr;
use super::ell::{balanced_strips, EllRb, GramScratch, K_BLOCK};
use crate::linalg::Mat;
use crate::util::threads::{num_threads, parallel_row_ranges_mut};

/// Row-wise concatenation of [`EllRb`] blocks sharing one column space and
/// stride R. Produced by the streaming featurizer; consumed by the
/// eigensolvers through [`crate::eigen::SvdOp`].
#[derive(Clone, Debug, PartialEq)]
pub struct BlockEllRb {
    pub rows: usize,
    pub cols: usize,
    /// Non-zeros per row (the paper's R), shared by all blocks.
    pub r: usize,
    /// Block b covers global rows `[row_offsets[b], row_offsets[b+1])`.
    pub row_offsets: Vec<usize>,
    pub blocks: Vec<EllRb>,
    /// nnz-balanced column-strip boundaries over the *combined* column
    /// occupancy, for the transpose kernels (same scheme as
    /// [`EllRb::t_bounds`]).
    t_bounds: Vec<usize>,
}

impl BlockEllRb {
    /// Stack `blocks` row-wise. All blocks must share `cols` and `r`;
    /// empty (zero-row) blocks are legal and contribute nothing.
    pub fn from_blocks(blocks: Vec<EllRb>) -> BlockEllRb {
        assert!(!blocks.is_empty(), "need at least one block");
        let cols = blocks[0].cols;
        let r = blocks[0].r;
        let mut row_offsets = Vec::with_capacity(blocks.len() + 1);
        row_offsets.push(0usize);
        for b in &blocks {
            assert_eq!(b.cols, cols, "blocks must share the column space");
            assert_eq!(b.r, r, "blocks must share the stride R");
            row_offsets.push(row_offsets.last().unwrap() + b.rows);
        }
        let rows = *row_offsets.last().unwrap();
        // Combined per-column nnz (sum of the blocks' CSC counts) drives
        // the strip balance; the cumulative form is only needed here.
        let mut col_ptr = vec![0usize; cols + 1];
        for b in &blocks {
            for c in 0..cols {
                col_ptr[c + 1] += b.col_ptr[c + 1] - b.col_ptr[c];
            }
        }
        for c in 0..cols {
            col_ptr[c + 1] += col_ptr[c];
        }
        let t_bounds = balanced_strips(&col_ptr, num_threads());
        BlockEllRb { rows, cols, r, row_offsets, blocks, t_bounds }
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn nnz(&self) -> usize {
        self.rows * self.r
    }

    /// y = Z·x — row-independent, so each block fills its own row range.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = Z·x into a caller-provided buffer (no allocation).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for (b, w) in self.blocks.iter().zip(self.row_offsets.windows(2)) {
            b.matvec_into(x, &mut y[w[0]..w[1]]);
        }
    }

    /// y = Zᵀ·x — each output entry is one running sum over the column's
    /// rows, walked block by block in ascending global row order (the
    /// exact accumulation order of the monolithic CSC kernel).
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.t_matvec_into(x, &mut y);
        y
    }

    /// y = Zᵀ·x into a caller-provided buffer (no allocation).
    pub fn t_matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        if self.cols == 0 {
            return;
        }
        let (blocks, row_offsets) = (&self.blocks, &self.row_offsets);
        parallel_row_ranges_mut(y, 1, &self.t_bounds, |_si, c0, chunk| {
            for (dc, yc) in chunk.iter_mut().enumerate() {
                let col = c0 + dc;
                let mut s = 0.0;
                for (b, off) in blocks.iter().zip(row_offsets.iter()) {
                    for p in b.col_ptr[col]..b.col_ptr[col + 1] {
                        let i = b.row_idx[p] as usize;
                        s += b.scale[i] * x[off + i];
                    }
                }
                *yc = s;
            }
        });
    }

    /// C = Z·B (rows×k): each block writes its own row range.
    pub fn matmat(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.rows, b.cols);
        self.matmat_into(b, &mut c);
        c
    }

    /// C = Z·B into a caller-owned matrix (reshaped as needed).
    pub fn matmat_into(&self, b: &Mat, out: &mut Mat) {
        assert_eq!(b.rows, self.cols, "matmat shape mismatch");
        let k = b.cols;
        if out.rows != self.rows || out.cols != k {
            out.reset(self.rows, k);
        }
        if k == 0 {
            return;
        }
        for (blk, w) in self.blocks.iter().zip(self.row_offsets.windows(2)) {
            blk.matmat_into_rows(b, &mut out.data[w[0] * k..w[1] * k]);
        }
    }

    /// C = Zᵀ·B (cols×k): per-column accumulation across blocks in
    /// ascending global row order — bit-identical to [`EllRb::t_matmat`]
    /// on the concatenated matrix.
    pub fn t_matmat(&self, b: &Mat) -> Mat {
        let mut c = Mat::zeros(self.cols, b.cols);
        self.t_matmat_into(b, &mut c);
        c
    }

    /// C = Zᵀ·B into a caller-owned matrix (reshaped as needed; every
    /// element is overwritten, so a dirty buffer is fine).
    pub fn t_matmat_into(&self, b: &Mat, out: &mut Mat) {
        assert_eq!(b.rows, self.rows, "t_matmat shape mismatch");
        let k = b.cols;
        if out.rows != self.cols || out.cols != k {
            out.reset(self.cols, k);
        }
        if self.cols == 0 || k == 0 {
            return;
        }
        let (blocks, row_offsets) = (&self.blocks, &self.row_offsets);
        parallel_row_ranges_mut(&mut out.data, k, &self.t_bounds, |_si, c0, chunk| {
            for (dc, crow) in chunk.chunks_mut(k).enumerate() {
                let col = c0 + dc;
                crow.fill(0.0);
                let mut kb = 0;
                while kb < k {
                    let ke = (kb + K_BLOCK).min(k);
                    let cblk = &mut crow[kb..ke];
                    for (blk, off) in blocks.iter().zip(row_offsets.iter()) {
                        for p in blk.col_ptr[col]..blk.col_ptr[col + 1] {
                            let i = blk.row_idx[p] as usize;
                            let si = blk.scale[i];
                            let brow = &b.row(off + i)[kb..ke];
                            for (cj, bj) in cblk.iter_mut().zip(brow.iter()) {
                                *cj += si * *bj;
                            }
                        }
                    }
                    kb = ke;
                }
            }
        });
    }

    /// Gram product C = Ẑ·(Ẑᵀ·B) (allocating convenience wrapper; the
    /// solver hot path uses [`BlockEllRb::gram_matmat_into`] with a reused
    /// [`GramScratch`]).
    pub fn gram_matmat(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(0, 0);
        let mut ws = GramScratch::new();
        self.gram_matmat_into(b, &mut out, &mut ws);
        out
    }

    /// Gram product through the scratch-resident D×k intermediate:
    /// `W = Ẑᵀ·B` into `ws.inter`, then `C = Ẑ·W` into `out`. Row-wise
    /// blocking couples every block through S = Ẑ·Ẑᵀ, so the intermediate
    /// cannot be tiled away — but it lives in the reusable scratch, so
    /// steady-state calls are allocation-free, and the result is
    /// bit-identical to the monolithic fused kernel (same per-element
    /// accumulation order on both passes).
    pub fn gram_matmat_into(&self, b: &Mat, out: &mut Mat, ws: &mut GramScratch) {
        assert_eq!(b.rows, self.rows, "gram_matmat shape mismatch");
        let k = b.cols;
        if out.rows != self.rows || out.cols != k {
            out.reset(self.rows, k);
        }
        if self.rows == 0 || k == 0 {
            return;
        }
        if self.cols == 0 {
            out.data.fill(0.0); // Zᵀ·B is empty ⇒ C = 0
            return;
        }
        // Borrow the intermediate out of the scratch for the duration of
        // the two passes (disjoint from anything `self` holds).
        let mut inter = std::mem::replace(&mut ws.inter, Mat::zeros(0, 0));
        self.t_matmat_into(b, &mut inter);
        self.matmat_into(&inter, out);
        ws.inter = inter;
    }

    /// Pre-provision `ws` for gram products up to block width `k_max`.
    pub fn prepare_gram(&self, ws: &mut GramScratch, k_max: usize) {
        ws.inter.reserve_for(self.cols, k_max);
    }

    /// Row sums Z·1 — closed form per block.
    pub fn row_sums(&self) -> Vec<f64> {
        let r = self.r as f64;
        self.blocks.iter().flat_map(|b| b.scale.iter().map(move |&s| s * r)).collect()
    }

    /// Column sums Zᵀ·1 (running per-column sum across blocks, ascending
    /// global row order — bit-identical to [`EllRb::col_sums`]).
    pub fn col_sums(&self) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        if self.cols == 0 {
            return y;
        }
        let blocks = &self.blocks;
        parallel_row_ranges_mut(&mut y, 1, &self.t_bounds, |_si, c0, chunk| {
            for (dc, yc) in chunk.iter_mut().enumerate() {
                let col = c0 + dc;
                let mut s = 0.0;
                for b in blocks.iter() {
                    for p in b.col_ptr[col]..b.col_ptr[col + 1] {
                        s += b.scale[b.row_idx[p] as usize];
                    }
                }
                *yc = s;
            }
        });
        y
    }

    /// Degree vector d = Z·(Zᵀ·1) (Equation 6), block-iterated.
    pub fn implicit_degrees(&self) -> Vec<f64> {
        let cs = self.col_sums();
        self.matvec(&cs)
    }

    /// Fold Ẑ = D^{-1/2}·Z into the per-block scale vectors — O(N).
    pub fn normalize_by_degree(&mut self, degrees: &[f64]) {
        assert_eq!(degrees.len(), self.rows);
        let offsets = &self.row_offsets;
        for (bi, blk) in self.blocks.iter_mut().enumerate() {
            blk.normalize_by_degree(&degrees[offsets[bi]..offsets[bi + 1]]);
        }
    }

    /// Multiply row i's shared value by s[i] — O(N).
    pub fn scale_rows(&mut self, s: &[f64]) {
        assert_eq!(s.len(), self.rows);
        let offsets = &self.row_offsets;
        for (bi, blk) in self.blocks.iter_mut().enumerate() {
            blk.scale_rows(&s[offsets[bi]..offsets[bi + 1]]);
        }
    }

    /// Diagonal of Z·Zᵀ — closed form R·scale[i]² per block.
    pub fn gram_diag(&self) -> Vec<f64> {
        let r = self.r as f64;
        self.blocks.iter().flat_map(|b| b.scale.iter().map(move |&s| r * s * s)).collect()
    }

    pub fn frob_norm(&self) -> f64 {
        let r = self.r as f64;
        self.blocks
            .iter()
            .flat_map(|b| b.scale.iter())
            .map(|&s| r * s * s)
            .sum::<f64>()
            .sqrt()
    }

    /// Concatenate into one monolithic [`EllRb`] (tests, small problems,
    /// bridging to code that wants the single-block substrate). This
    /// materializes a second copy of the indices — the streaming path
    /// never calls it on big data.
    pub fn to_ell(&self) -> EllRb {
        let mut indices = Vec::with_capacity(self.rows * self.r);
        let mut scale = Vec::with_capacity(self.rows);
        for b in &self.blocks {
            indices.extend_from_slice(&b.indices);
            scale.extend_from_slice(&b.scale);
        }
        EllRb::new(self.rows, self.cols, self.r, indices, scale)
    }

    /// Bridge to general CSR (via the monolithic view).
    pub fn to_csr(&self) -> Csr {
        self.to_ell().to_csr()
    }

    /// Memory footprint in bytes (all blocks + the block index).
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).sum::<usize>()
            + self.row_offsets.len() * 8
            + self.t_bounds.len() * 8
    }

    /// Largest single block's footprint in bytes — the streaming memory
    /// bound reported by `bench_ingest`.
    pub fn peak_block_bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eigen::SvdOp;
    use crate::util::rng::Pcg;

    /// Random monolithic EllRb with RB structure plus the same data cut
    /// into row blocks at the given boundaries.
    fn random_pair(
        rng: &mut Pcg,
        rows: usize,
        r: usize,
        bins_per_grid: usize,
        cuts: &[usize],
    ) -> (EllRb, BlockEllRb) {
        let cols = r * bins_per_grid;
        let mut indices = Vec::with_capacity(rows * r);
        for _ in 0..rows {
            for j in 0..r {
                indices.push((j * bins_per_grid + rng.below(bins_per_grid)) as u32);
            }
        }
        let scale: Vec<f64> = (0..rows).map(|_| rng.range_f64(0.1, 2.0)).collect();
        let mono = EllRb::new(rows, cols, r, indices.clone(), scale.clone());
        let mut bounds = vec![0usize];
        bounds.extend_from_slice(cuts);
        bounds.push(rows);
        let blocks: Vec<EllRb> = bounds
            .windows(2)
            .map(|w| {
                EllRb::new(
                    w[1] - w[0],
                    cols,
                    r,
                    indices[w[0] * r..w[1] * r].to_vec(),
                    scale[w[0]..w[1]].to_vec(),
                )
            })
            .collect();
        (mono, BlockEllRb::from_blocks(blocks))
    }

    #[test]
    fn products_are_bit_identical_to_monolithic() {
        let mut rng = Pcg::seed(301);
        for cuts in [&[][..], &[17][..], &[5, 5, 40][..]] {
            let (mono, blocked) = random_pair(&mut rng, 50, 6, 5, cuts);
            assert_eq!(blocked.rows, 50);
            assert_eq!(blocked.nnz(), mono.nnz());
            let x: Vec<f64> = (0..mono.cols).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            assert_eq!(blocked.matvec(&x), mono.matvec(&x));
            let u: Vec<f64> = (0..mono.rows).map(|_| rng.range_f64(-1.0, 1.0)).collect();
            assert_eq!(blocked.t_matvec(&u), mono.t_matvec(&u));
            for &k in &[1usize, 3, 8, K_BLOCK + 5] {
                let b = Mat::from_vec(
                    mono.cols,
                    k,
                    (0..mono.cols * k).map(|_| rng.range_f64(-1.0, 1.0)).collect(),
                );
                assert_eq!(blocked.matmat(&b).data, mono.matmat(&b).data, "matmat k={k}");
                let b2 = Mat::from_vec(
                    mono.rows,
                    k,
                    (0..mono.rows * k).map(|_| rng.range_f64(-1.0, 1.0)).collect(),
                );
                assert_eq!(
                    blocked.t_matmat(&b2).data,
                    mono.t_matmat(&b2).data,
                    "t_matmat k={k}"
                );
            }
            assert_eq!(blocked.col_sums(), mono.col_sums());
            assert_eq!(blocked.row_sums(), mono.row_sums());
            assert_eq!(blocked.gram_diag(), mono.gram_diag());
            assert_eq!(blocked.implicit_degrees(), mono.implicit_degrees());
            assert_eq!(blocked.frob_norm(), mono.frob_norm());
            assert_eq!(blocked.to_ell(), mono);
        }
    }

    #[test]
    fn fused_gram_is_bit_identical_to_monolithic_fused() {
        // The streamed-fit bit-exactness contract hinges on this: the
        // block substrate's transpose-then-forward gram must equal the
        // monolithic strip-tiled fused kernel bit for bit.
        let mut rng = Pcg::seed(302);
        let (mono, blocked) = random_pair(&mut rng, 64, 8, 4, &[10, 30]);
        let mut mono_ws = GramScratch::new();
        let mut blk_ws = GramScratch::new();
        let mut mono_out = Mat::zeros(0, 0);
        let mut blk_out = Mat::zeros(0, 0);
        for &k in &[1usize, 4, 9] {
            let b = Mat::from_vec(
                mono.rows,
                k,
                (0..mono.rows * k).map(|_| rng.range_f64(-1.0, 1.0)).collect(),
            );
            mono.gram_matmat_into(&b, &mut mono_out, &mut mono_ws);
            blocked.gram_matmat_into(&b, &mut blk_out, &mut blk_ws);
            assert_eq!(blk_out.data, mono_out.data, "fused gram k={k}");
            // dirty-out steady state must fully overwrite
            blocked.gram_matmat_into(&b, &mut blk_out, &mut blk_ws);
            assert_eq!(blk_out.data, mono_out.data, "dirty-out k={k}");
        }
    }

    #[test]
    fn degree_normalization_matches_monolithic() {
        let mut rng = Pcg::seed(303);
        let (mut mono, mut blocked) = random_pair(&mut rng, 40, 5, 3, &[12, 25]);
        let dm = mono.implicit_degrees();
        let db = blocked.implicit_degrees();
        assert_eq!(dm, db);
        mono.normalize_by_degree(&dm);
        blocked.normalize_by_degree(&db);
        assert_eq!(blocked.to_ell(), mono);
        // scale_rows parity too
        let s: Vec<f64> = (0..40).map(|_| rng.range_f64(0.5, 1.5)).collect();
        mono.scale_rows(&s);
        blocked.scale_rows(&s);
        assert_eq!(blocked.to_ell(), mono);
    }

    #[test]
    fn svd_op_surface_matches_monolithic() {
        let mut rng = Pcg::seed(304);
        let (mono, blocked) = random_pair(&mut rng, 30, 4, 6, &[9, 20]);
        assert_eq!(SvdOp::nrows(&blocked), 30);
        assert_eq!(SvdOp::ncols(&blocked), mono.cols);
        let b = Mat::from_vec(mono.cols, 3, (0..mono.cols * 3).map(|_| rng.f64()).collect());
        assert_eq!(SvdOp::apply(&blocked, &b).data, SvdOp::apply(&mono, &b).data);
        let b2 = Mat::from_vec(30, 3, (0..90).map(|_| rng.f64()).collect());
        assert_eq!(SvdOp::apply_t(&blocked, &b2).data, SvdOp::apply_t(&mono, &b2).data);
        assert_eq!(SvdOp::gram_matmat(&blocked, &b2).data, SvdOp::gram_matmat(&mono, &b2).data);
        let x: Vec<f64> = (0..mono.cols).map(|_| rng.f64()).collect();
        let mut ya = vec![0.0; 30];
        let mut yb = vec![0.0; 30];
        SvdOp::apply_vec_into(&blocked, &x, &mut ya);
        SvdOp::apply_vec_into(&mono, &x, &mut yb);
        assert_eq!(ya, yb);
        let u: Vec<f64> = (0..30).map(|_| rng.f64()).collect();
        let mut ta = vec![0.0; mono.cols];
        let mut tb = vec![0.0; mono.cols];
        SvdOp::apply_t_vec_into(&blocked, &u, &mut ta);
        SvdOp::apply_t_vec_into(&mono, &u, &mut tb);
        assert_eq!(ta, tb);
        assert_eq!(SvdOp::gram_diag(&blocked), SvdOp::gram_diag(&mono));
    }

    #[test]
    fn empty_final_block_and_single_block() {
        let mut rng = Pcg::seed(305);
        // single block: the degenerate concatenation
        let (mono, single) = random_pair(&mut rng, 20, 3, 4, &[]);
        assert_eq!(single.n_blocks(), 1);
        assert_eq!(single.to_ell(), mono);
        // empty final block (a chunk boundary landing exactly on N)
        let (mono2, with_empty) = random_pair(&mut rng, 20, 3, 4, &[20]);
        assert_eq!(with_empty.n_blocks(), 2);
        assert_eq!(with_empty.blocks[1].rows, 0);
        assert_eq!(with_empty.rows, 20);
        let x: Vec<f64> = (0..mono2.cols).map(|_| rng.f64()).collect();
        assert_eq!(with_empty.matvec(&x), mono2.matvec(&x));
        let u: Vec<f64> = (0..20).map(|_| rng.f64()).collect();
        assert_eq!(with_empty.t_matvec(&u), mono2.t_matvec(&u));
        let b = Mat::from_vec(20, 2, (0..40).map(|_| rng.f64()).collect());
        assert_eq!(with_empty.gram_matmat(&b).data, mono2.gram_matmat(&b).data);
        assert_eq!(with_empty.to_ell(), mono2);
        // empty *leading* block as well
        let (mono3, lead_empty) = random_pair(&mut rng, 15, 2, 5, &[0, 7]);
        assert_eq!(lead_empty.blocks[0].rows, 0);
        assert_eq!(lead_empty.to_ell(), mono3);
    }

    #[test]
    fn solver_runs_on_block_substrate_identically() {
        // end-to-end: every solver on blocked vs monolithic Ẑ agrees bitwise
        use crate::eigen::{svds, SvdsOpts};
        let mut rng = Pcg::seed(306);
        let (mut mono, mut blocked) = random_pair(&mut rng, 80, 6, 7, &[33, 60]);
        let d = mono.implicit_degrees();
        mono.normalize_by_degree(&d);
        blocked.normalize_by_degree(&d);
        for solver in crate::config::Solver::ALL {
            let opts = SvdsOpts::new(3, solver);
            let a = svds(&mono, &opts, 7);
            let b = svds(&blocked, &opts, 7);
            assert_eq!(a.s, b.s, "{solver:?} singular values");
            assert_eq!(a.u.data, b.u.data, "{solver:?} U");
            assert_eq!(a.v.data, b.v.data, "{solver:?} V");
            assert_eq!(a.stats.matvecs, b.stats.matvecs);
        }
    }
}
