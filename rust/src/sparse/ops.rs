//! Graph-Laplacian operations on the implicit similarity matrix Ŵ = Z·Zᵀ
//! — the paper's §3.1 trick: everything is expressed through Z without
//! ever materializing the N×N matrix.
//!
//! These free functions operate on the general [`Csr`] substrate. The RB
//! pipeline itself runs on [`super::EllRb`], whose inherent
//! `implicit_degrees` / `normalize_by_degree` are the fixed-stride
//! equivalents (property-tested to agree in `tests/properties.rs`).

use super::csr::Csr;
use crate::linalg::Mat;

/// Degree vector of the implicit similarity graph:
/// d = Ŵ·1 = Z·(Zᵀ·1)  (Equation 6 — two sparse matvecs).
pub fn implicit_degrees(z: &Csr) -> Vec<f64> {
    let col_sums = z.col_sums();
    z.matvec(&col_sums)
}

/// Build Ẑ = D^{-1/2}·Z from Z (consumes a copy of Z). Rows with zero or
/// negative degree (possible only if Z had no entries, or numerically ~0)
/// are left unscaled.
pub fn normalize_by_degree(mut z: Csr, degrees: &[f64]) -> Csr {
    let scale: Vec<f64> =
        degrees.iter().map(|&d| if d > 1e-300 { 1.0 / d.sqrt() } else { 0.0 }).collect();
    z.scale_rows(&scale);
    z
}

/// Apply the implicit normalized similarity S = Ẑ·Ẑᵀ to a block:
/// Y = Ẑ·(Ẑᵀ·B). The smallest eigenvectors of L̂ = I − S are the largest
/// of S, i.e. the largest left singular vectors of Ẑ.
///
/// This is the *two-pass reference* of the gram contract (it materializes
/// the D×k intermediate). The solver hot path uses
/// [`crate::eigen::SvdOp::gram_matmat_into`] instead, which `EllRb` fuses
/// into one strip-tiled pass with cache-sized scratch; the two are
/// property-tested to agree to 1e-12 in `tests/properties.rs`.
pub fn apply_normalized_similarity(zhat: &Csr, b: &Mat) -> Mat {
    let t = zhat.t_matmat(b); // D×k
    zhat.matmat(&t) // N×k
}

/// Materialize the exact normalized Laplacian L = I − D^{-1/2} W D^{-1/2}
/// from a *dense* similarity matrix (exact-SC baseline; small N only).
pub fn normalized_laplacian_dense(w: &Mat) -> Mat {
    let n = w.rows;
    assert_eq!(w.rows, w.cols);
    let mut deg = vec![0.0; n];
    for i in 0..n {
        deg[i] = w.row(i).iter().sum();
    }
    let scale: Vec<f64> =
        deg.iter().map(|&d| if d > 1e-300 { 1.0 / d.sqrt() } else { 0.0 }).collect();
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let v = -scale[i] * w.at(i, j) * scale[j];
            l.set(i, j, if i == j { 1.0 + v } else { v });
        }
    }
    l
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, rows: usize, cols: usize, per_row: usize) -> Csr {
        let mut entries = Vec::with_capacity(rows);
        for _ in 0..rows {
            let mut r = Vec::with_capacity(per_row);
            for _ in 0..per_row {
                r.push((rng.below(cols) as u32, rng.f64() + 0.1));
            }
            entries.push(r);
        }
        Csr::from_rows(rows, cols, entries)
    }

    #[test]
    fn implicit_degrees_match_explicit_gram() {
        let mut rng = Pcg::seed(51);
        let z = random_csr(&mut rng, 30, 20, 3);
        let d = implicit_degrees(&z);
        let w = z.gram_dense();
        for i in 0..30 {
            let expl: f64 = w.row(i).iter().sum();
            assert!((d[i] - expl).abs() < 1e-10, "row {i}: {} vs {expl}", d[i]);
        }
    }

    #[test]
    fn normalized_similarity_matches_dense() {
        let mut rng = Pcg::seed(52);
        let z = random_csr(&mut rng, 25, 15, 3);
        let d = implicit_degrees(&z);
        let zhat = normalize_by_degree(z.clone(), &d);
        let b = Mat::from_vec(25, 4, (0..100).map(|_| rng.f64()).collect());
        let y = apply_normalized_similarity(&zhat, &b);
        // dense reference: D^{-1/2} W D^{-1/2} B
        let w = z.gram_dense();
        let mut s = Mat::zeros(25, 25);
        for i in 0..25 {
            for j in 0..25 {
                s.set(i, j, w.at(i, j) / (d[i].sqrt() * d[j].sqrt()));
            }
        }
        let y0 = s.matmul(&b);
        assert!(y.sub(&y0).frob_norm() < 1e-10);
    }

    #[test]
    fn laplacian_dense_psd_and_zero_mode() {
        // L is PSD and L·(D^{1/2}·1) = 0 for a connected graph.
        let mut rng = Pcg::seed(53);
        let z = random_csr(&mut rng, 12, 6, 3);
        let w = z.gram_dense();
        let l = normalized_laplacian_dense(&w);
        let deg: Vec<f64> = (0..12).map(|i| w.row(i).iter().sum::<f64>()).collect();
        let v: Vec<f64> = deg.iter().map(|d| d.sqrt()).collect();
        let lv = l.matvec(&v);
        let vnorm = crate::linalg::nrm2(&v);
        for x in lv {
            assert!(x.abs() < 1e-9 * vnorm, "kernel vector residual {x}");
        }
        // symmetry
        for i in 0..12 {
            for j in 0..12 {
                assert!((l.at(i, j) - l.at(j, i)).abs() < 1e-12);
            }
        }
    }
}
