//! One driver per paper table/figure (DESIGN.md §6). Each returns plain
//! data that `report` renders; the `scrb` CLI, the `examples/repro_*`
//! binaries, and the benches all call these.

use super::{Coordinator, MethodRun};
use crate::cluster::{Env, MethodKind};
use crate::error::ScrbError;
use crate::config::Solver;
use crate::data::{synth, Dataset};
use crate::eigen::{svds_ws, SolverWorkspace, SvdsOpts};
use crate::linalg::Mat;
use crate::metrics::average_rank_scores;
use crate::rb::{exact_laplacian_gram, rb_features};
use std::time::Instant;

/// Datasets of Table 1, in paper order.
pub const TABLE_DATASETS: [&str; 8] = [
    "pendigits",
    "letter",
    "mnist",
    "acoustic",
    "ijcnn1",
    "cod_rna",
    "covtype-mult",
    "poker",
];

/// Build (synthetic stand-in) benchmark `name` under the coordinator's
/// scale.
pub fn dataset(coord: &Coordinator, name: &str) -> Dataset {
    synth::paper_benchmark(name, coord.scale, coord.base_cfg.seed)
}

// ---------------------------------------------------------------- Table 2+3

/// Full comparison grid: every method × every requested dataset.
/// Returns per-dataset: (dataset name, N, per-method runs in
/// `MethodKind::ALL` order; infeasible methods are `None`).
pub struct GridResult {
    pub datasets: Vec<GridRow>,
}

pub struct GridRow {
    pub name: String,
    pub n: usize,
    pub runs: Vec<Option<MethodRun>>,
    /// Average rank score per method (NaN for methods that did not run).
    pub ranks: Vec<f64>,
}

pub fn table2_3(coord: &Coordinator, datasets: &[String]) -> Result<GridResult, ScrbError> {
    let mut rows = Vec::new();
    for name in datasets {
        // one dataset's artifacts never serve another; bound memory
        coord.clear_cache();
        let ds = dataset(coord, name);
        let cfg = coord.cfg_for(&ds, None);
        if coord.verbose {
            eprintln!("[table2/3] {} n={} d={} k={} sigma={:.3}", ds.name, ds.n(), ds.d(), ds.k, cfg.kernel.sigma());
        }
        let mut runs: Vec<Option<MethodRun>> = Vec::new();
        for kind in MethodKind::ALL {
            if kind == MethodKind::ScExact && !coord.exact_sc_feasible(ds.n()) {
                runs.push(None);
                continue;
            }
            runs.push(Some(coord.run_method(kind, &ds, &cfg)?));
        }
        // rank over the methods that ran; NaN keeps non-runners last
        let scores: Vec<crate::metrics::ClusterMetrics> = runs
            .iter()
            .map(|r| {
                r.as_ref().map(|m| m.metrics).unwrap_or(crate::metrics::ClusterMetrics {
                    nmi: f64::NAN,
                    rand_index: f64::NAN,
                    f_measure: f64::NAN,
                    accuracy: f64::NAN,
                })
            })
            .collect();
        let mut ranks = average_rank_scores(&scores);
        for (i, r) in runs.iter().enumerate() {
            if r.is_none() {
                ranks[i] = f64::NAN;
            }
        }
        rows.push(GridRow { name: ds.name.clone(), n: ds.n(), runs, ranks });
    }
    Ok(GridResult { datasets: rows })
}

// ------------------------------------------------------------------- Fig. 2

/// One point of a figure series.
#[derive(Clone, Debug)]
pub struct SeriesPoint {
    pub x: f64,
    pub acc: f64,
    pub secs: f64,
}

#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub points: Vec<SeriesPoint>,
}

/// Fig. 2: accuracy + runtime vs R on the mnist-like benchmark for the
/// random-feature methods, with the exact-SC accuracy as reference.
pub struct Fig2Result {
    pub series: Vec<Series>,
    /// Exact SC reference (run at the feasibility cap): (n, acc, secs).
    pub exact_ref: Option<(usize, f64, f64)>,
}

pub fn fig2(
    coord: &Coordinator,
    rs: &[usize],
    rb_max_r: usize,
) -> Result<Fig2Result, ScrbError> {
    let ds = dataset(coord, "mnist");
    let cfg0 = coord.cfg_for(&ds, None);
    let methods = [MethodKind::ScRb, MethodKind::ScRf, MethodKind::SvRf, MethodKind::KkRf];
    // R outer, methods inner: the RF-family methods share one cached
    // featurization per R, and the per-R cache clear bounds peak memory
    // to one grid point's artifacts instead of the whole sweep's
    let mut points: Vec<Vec<SeriesPoint>> = vec![Vec::new(); methods.len()];
    for &r in rs {
        coord.clear_cache();
        // validated sweep point (no field pokes)
        let cfg = cfg0.rebuild(|b| b.r(r))?;
        for (mi, &kind) in methods.iter().enumerate() {
            // the paper sweeps SC_RB only to 1024 (it converges by then)
            if kind == MethodKind::ScRb && r > rb_max_r {
                continue;
            }
            let run = coord.run_method(kind, &ds, &cfg)?;
            points[mi].push(SeriesPoint { x: r as f64, acc: run.metrics.accuracy, secs: run.secs });
        }
    }
    coord.clear_cache();
    let series: Vec<Series> = methods
        .iter()
        .zip(points)
        .map(|(kind, points)| Series { label: kind.name().to_string(), points })
        .collect();
    // exact SC reference on a feasible subset
    let exact_ref = if coord.exact_sc_feasible(ds.n()) {
        let run = coord.run_method(MethodKind::ScExact, &ds, &cfg0)?;
        Some((ds.n(), run.metrics.accuracy, run.secs))
    } else {
        let mut small = ds.clone();
        small.truncate(8_000.min(ds.n()));
        let cfg = coord.cfg_for(&small, Some(cfg0.kernel.sigma()));
        let run = coord.run_method(MethodKind::ScExact, &small, &cfg)?;
        Some((small.n(), run.metrics.accuracy, run.secs))
    };
    Ok(Fig2Result { series, exact_ref })
}

// ------------------------------------------------------------------- Fig. 3

/// Fig. 3: SC_RB accuracy + runtime vs R on covtype-like under the three
/// SVD solvers (PRIMME-analogue Davidson, Matlab-svds-analogue Lanczos,
/// and the Chebyshev-filter compressive backend).
pub fn fig3(coord: &Coordinator, rs: &[usize]) -> Result<Vec<Series>, ScrbError> {
    coord.clear_cache();
    let ds = dataset(coord, "covtype-mult");
    let cfg0 = coord.cfg_for(&ds, None);
    let mut out = Vec::new();
    for (solver, label) in [
        (Solver::Davidson, "PRIMME_SVDS (davidson)"),
        (Solver::Lanczos, "SVDS (lanczos)"),
        (Solver::Compressive, "CSC (compressive)"),
    ] {
        let mut points = Vec::new();
        for &r in rs {
            // the solver is an embed-stage knob: the second solver's
            // sweep reuses every cached RB featurization from the first
            let cfg = cfg0.rebuild(|b| b.r(r).solver(solver))?;
            let run = coord.run_method(MethodKind::ScRb, &ds, &cfg)?;
            points.push(SeriesPoint { x: r as f64, acc: run.metrics.accuracy, secs: run.secs });
        }
        out.push(Series { label: label.to_string(), points });
    }
    Ok(out)
}

// ------------------------------------------------------------------- Fig. 4

/// Per-stage timing of SC_RB at one N (Fig. 4 series).
#[derive(Clone, Debug)]
pub struct ScalePoint {
    pub n: usize,
    pub rb_secs: f64,
    pub svd_secs: f64,
    /// Serving-model preparation: Σ/V projection fold + the training-set
    /// embedding/label pass (the fit-side half of the model API).
    pub embed_secs: f64,
    pub kmeans_secs: f64,
    pub total_secs: f64,
    pub accuracy: f64,
}

/// Fig. 4: SC_RB runtime decomposition while N sweeps (poker-like and
/// susy-like), fixed R.
pub fn fig4(
    coord: &Coordinator,
    dataset_name: &str,
    ns: &[usize],
    r: usize,
) -> Result<Vec<ScalePoint>, ScrbError> {
    let spec = synth::spec_by_name(dataset_name).expect("unknown dataset");
    let mut out = Vec::new();
    for &n in ns {
        // every scale point synthesizes different data, so nothing from
        // the previous point can hit — clear per point to keep the peak
        // at one substrate, not the sum over the sweep
        coord.clear_cache();
        let scale = (spec.n / n.max(1)).max(1);
        let mut ds = synth::paper_benchmark(dataset_name, scale, coord.base_cfg.seed);
        ds.truncate(n.min(ds.n()));
        let cfg = coord.cfg_for(&ds, None).rebuild(|b| b.r(r))?;
        let run = coord.run_method(MethodKind::ScRb, &ds, &cfg)?;
        let stage = |name: &str| {
            run.stages.iter().find(|(s, _)| s == name).map(|(_, t)| *t).unwrap_or(0.0)
        };
        out.push(ScalePoint {
            n: ds.n(),
            rb_secs: stage("rb_features"),
            svd_secs: stage("svd") + stage("degrees"),
            embed_secs: stage("projection") + stage("embed"),
            kmeans_secs: stage("kmeans"),
            total_secs: run.secs,
            accuracy: run.metrics.accuracy,
        });
    }
    Ok(out)
}

// ------------------------------------------------------------------- Fig. 5

/// Fig. 5: runtime vs R for all methods on one dataset (4 panels in the
/// paper: pendigits, letter, mnist, acoustic).
pub fn fig5(
    coord: &Coordinator,
    dataset_name: &str,
    rs: &[usize],
) -> Result<Vec<Series>, ScrbError> {
    coord.clear_cache();
    let ds = dataset(coord, dataset_name);
    let cfg0 = coord.cfg_for(&ds, None);
    // R outer, methods inner: same-R featurizations are shared across
    // methods while the per-R clear bounds peak memory to one grid point
    let mut per_method: Vec<Vec<SeriesPoint>> = vec![Vec::new(); MethodKind::ALL.len()];
    for &r in rs {
        coord.clear_cache();
        let cfg = cfg0.rebuild(|b| b.r(r))?;
        for (mi, &kind) in MethodKind::ALL.iter().enumerate() {
            if kind == MethodKind::ScExact {
                continue; // R-independent; handled once below
            }
            let run = coord.run_method(kind, &ds, &cfg)?;
            per_method[mi]
                .push(SeriesPoint { x: r as f64, acc: run.metrics.accuracy, secs: run.secs });
        }
    }
    coord.clear_cache();
    let mut out = Vec::new();
    for (mi, &kind) in MethodKind::ALL.iter().enumerate() {
        if kind == MethodKind::ScExact {
            // quadratic reference: run once (R-independent) if feasible
            if coord.exact_sc_feasible(ds.n()) {
                let run = coord.run_method(kind, &ds, &cfg0)?;
                let points = rs
                    .iter()
                    .map(|&r| SeriesPoint { x: r as f64, acc: run.metrics.accuracy, secs: run.secs })
                    .collect();
                out.push(Series { label: kind.name().to_string(), points });
            }
            continue;
        }
        out.push(Series {
            label: kind.name().to_string(),
            points: std::mem::take(&mut per_method[mi]),
        });
    }
    Ok(out)
}

// ----------------------------------------------------- Theorem 1/2 empirics

/// Empirical convergence of the RB spectral objective to the exact one:
/// gap(R) = f(Û_R) − f(U*) where f(U) = trace(Uᵀ·L·U) under the *exact*
/// normalized Laplacian. Theorem 2 predicts gap ≲ C/(κ·R).
#[derive(Clone, Debug)]
pub struct TheoryPoint {
    pub r: usize,
    pub kappa: f64,
    pub gap: f64,
    pub predicted_slope: f64,
}

pub fn theory_convergence(
    coord: &Coordinator,
    n: usize,
    rs: &[usize],
) -> Result<Vec<TheoryPoint>, ScrbError> {
    let mut ds = synth::gaussian_blobs(n, 4, 3, 6.0, coord.base_cfg.seed);
    ds.minmax_normalize();
    let cfg = coord.cfg_for(&ds, None);
    let sigma = cfg.kernel.sigma();
    let k = cfg.k;

    // exact normalized similarity S and its top-k eigenbasis
    let w = exact_laplacian_gram(&ds.x, sigma);
    let s = {
        let n_ = w.rows;
        let mut scale = vec![0.0; n_];
        for i in 0..n_ {
            scale[i] = 1.0 / w.row(i).iter().sum::<f64>().sqrt();
        }
        let mut s = w.clone();
        for i in 0..n_ {
            for j in 0..n_ {
                s.set(i, j, scale[i] * s.at(i, j) * scale[j]);
            }
        }
        s
    };
    let objective = |u: &Mat| -> f64 {
        // trace(Uᵀ L U) = k − trace(Uᵀ S U)
        let su = s.matmul(u);
        let m = u.t_matmul(&su);
        (0..u.cols).map(|j| 1.0 - m.at(j, j)).sum()
    };
    // One SolverWorkspace amortized over the exact solve and the whole R
    // sweep: the gram scratch re-provisions itself when the operator
    // changes, and all solver buffers are reused across solves.
    let mut solver_ws = SolverWorkspace::new();
    let exact_op = crate::cluster::sc_exact::SymOp(&s);
    let mut opts = SvdsOpts::new(k, Solver::Davidson);
    opts.tol = 1e-9;
    opts.max_matvecs = 50_000;
    let exact_u = svds_ws(&exact_op, &opts, 7, &mut solver_ws).u;
    let f_star = objective(&exact_u);

    let mut out = Vec::new();
    for &r in rs {
        let rb = rb_features(&ds.x, r, sigma, coord.base_cfg.seed ^ 0x7e0);
        let kappa = rb.kappa;
        let mut zhat = rb.z;
        let d = zhat.implicit_degrees();
        zhat.normalize_by_degree(&d);
        let mut o = SvdsOpts::new(k, Solver::Davidson);
        o.tol = 1e-8;
        o.max_matvecs = 50_000;
        let u = svds_ws(&zhat, &o, 9, &mut solver_ws).u;
        let gap = (objective(&u) - f_star).max(0.0);
        out.push(TheoryPoint { r, kappa, gap, predicted_slope: 1.0 / (kappa * r as f64) });
    }
    Ok(out)
}

// -------------------------------------------------------------- single runs

/// Run one named method on one benchmark (the `scrb run` command).
pub fn single_run(
    coord: &Coordinator,
    method: MethodKind,
    ds: &Dataset,
    sigma_override: Option<f64>,
) -> Result<MethodRun, ScrbError> {
    let cfg = coord.cfg_for(ds, sigma_override);
    coord.run_method(method, ds, &cfg)
}

/// Sanity helper used by tests and the quickstart: SC_RB on two moons via
/// a bare Env (no coordinator).
pub fn smoke_run() -> f64 {
    let ds = synth::two_moons(400, 0.06, 3);
    let cfg = crate::config::PipelineConfig::builder()
        .k(2)
        .r(128)
        .kernel(crate::config::Kernel::Laplacian { sigma: 0.15 })
        .kmeans_replicates(3)
        .build();
    let env = Env::new(cfg);
    let t0 = Instant::now();
    let out = MethodKind::ScRb.run(&env, &ds.x).expect("SC_RB smoke run failed");
    let _ = t0.elapsed();
    crate::metrics::accuracy(&out.labels, &ds.y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Engine, PipelineConfig};

    fn quick_coord() -> Coordinator {
        let cfg = PipelineConfig::builder()
            .engine(Engine::Native)
            .r(32)
            .kmeans_replicates(2)
            .svd_max_iters(2000)
            .build();
        Coordinator::new(cfg, 2048)
    }

    #[test]
    fn grid_runs_tiny() {
        let coord = quick_coord();
        let grid = table2_3(&coord, &["pendigits".to_string()]).unwrap();
        assert_eq!(grid.datasets.len(), 1);
        let row = &grid.datasets[0];
        assert_eq!(row.runs.len(), MethodKind::ALL.len());
        // all methods ran at this tiny scale (exact SC included)
        assert!(row.runs.iter().all(|r| r.is_some()));
        // ranks are a permutation-ish set with mean (m+1)/2
        let m = row.ranks.len() as f64;
        let mean: f64 = row.ranks.iter().sum::<f64>() / m;
        assert!((mean - (m + 1.0) / 2.0).abs() < 1e-9, "ranks {:?}", row.ranks);
    }

    #[test]
    fn theory_gap_shrinks() {
        let coord = quick_coord();
        let pts = theory_convergence(&coord, 150, &[8, 128]).unwrap();
        assert_eq!(pts.len(), 2);
        assert!(
            pts[1].gap <= pts[0].gap + 1e-9,
            "gap should shrink with R: {:?}",
            pts
        );
    }

    #[test]
    fn smoke_clusters_moons() {
        assert!(smoke_run() > 0.85);
    }
}
