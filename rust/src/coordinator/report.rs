//! Rendering of experiment results as paper-style text tables, CSV files,
//! and JSON blobs under `results/`.

use super::experiment::{Fig2Result, GridResult, ScalePoint, Series, TheoryPoint};
use crate::cluster::MethodKind;
use crate::util::json::Json;
use crate::util::table::{fnum, Table};

/// Render Table 1 (dataset properties).
pub fn render_table1(scale: usize) -> String {
    let mut t = Table::new(vec!["Name", "K: Classes", "d: Features", "N: Samples", "N (scaled)"]);
    for spec in crate::data::PAPER_BENCHMARKS {
        let scaled = (spec.n / scale.max(1)).max(64 * spec.k);
        t.row(vec![
            spec.name.to_string(),
            spec.k.to_string(),
            spec.d.to_string(),
            spec.n.to_string(),
            scaled.to_string(),
        ]);
    }
    t.render()
}

/// Render Table 2 (average rank scores — lower is better).
pub fn render_table2(grid: &GridResult) -> String {
    let mut header: Vec<String> = vec!["Dataset".into()];
    header.extend(MethodKind::ALL.iter().map(|m| m.name().to_string()));
    let mut t = Table::new(header);
    for row in &grid.datasets {
        let mut cells = vec![row.name.clone()];
        for r in &row.ranks {
            cells.push(if r.is_nan() { "-".to_string() } else { format!("{r:.2}") });
        }
        t.row(cells);
    }
    t.render()
}

/// Render Table 3 (computational time, seconds).
pub fn render_table3(grid: &GridResult) -> String {
    let mut header: Vec<String> = vec!["Dataset".into()];
    header.extend(MethodKind::ALL.iter().map(|m| m.name().to_string()));
    let mut t = Table::new(header);
    for row in &grid.datasets {
        let mut cells = vec![row.name.clone()];
        for r in &row.runs {
            cells.push(match r {
                Some(run) => fnum(run.secs),
                None => "-".to_string(),
            });
        }
        t.row(cells);
    }
    t.render()
}

/// Per-metric detail table (one dataset): methods × (NMI, RI, FM, Acc, s).
pub fn render_detail(grid: &GridResult) -> String {
    let mut out = String::new();
    for row in &grid.datasets {
        out.push_str(&format!("== {} (N={}) ==\n", row.name, row.n));
        let mut t =
            Table::new(vec!["Method", "NMI", "RI", "FM", "Acc", "AvgRank", "Time(s)", "SVD mv"]);
        for (i, r) in row.runs.iter().enumerate() {
            match r {
                Some(run) => {
                    t.row(vec![
                        run.method.name().to_string(),
                        format!("{:.3}", run.metrics.nmi),
                        format!("{:.3}", run.metrics.rand_index),
                        format!("{:.3}", run.metrics.f_measure),
                        format!("{:.3}", run.metrics.accuracy),
                        format!("{:.2}", row.ranks[i]),
                        fnum(run.secs),
                        run.svd_matvecs.to_string(),
                    ]);
                }
                None => {
                    t.row(vec![
                        MethodKind::ALL[i].name().to_string(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                        "-".into(),
                    ]);
                }
            }
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out
}

/// Render a figure's series as an aligned table: one block per series.
pub fn render_series(title: &str, series: &[Series], xname: &str) -> String {
    let mut out = format!("== {title} ==\n");
    for s in series {
        out.push_str(&format!("-- {} --\n", s.label));
        let mut t = Table::new(vec![xname, "Acc", "Time(s)"]);
        for p in &s.points {
            t.row(vec![format!("{}", p.x as usize), format!("{:.3}", p.acc), fnum(p.secs)]);
        }
        out.push_str(&t.render());
    }
    out
}

pub fn render_fig2(fig: &Fig2Result) -> String {
    let mut out = render_series("Fig. 2: accuracy & runtime vs R (mnist-like)", &fig.series, "R");
    if let Some((n, acc, secs)) = fig.exact_ref {
        out.push_str(&format!(
            "-- exact SC reference -- (N={n})\nacc={acc:.3} time={}\n",
            fnum(secs)
        ));
    }
    out
}

pub fn render_fig4(dataset: &str, points: &[ScalePoint]) -> String {
    let mut out = format!("== Fig. 4: SC_RB scalability in N ({dataset}) ==\n");
    let mut t = Table::new(vec!["N", "RB(s)", "SVD(s)", "Embed(s)", "KMeans(s)", "Total(s)", "Acc"]);
    for p in points {
        t.row(vec![
            p.n.to_string(),
            fnum(p.rb_secs),
            fnum(p.svd_secs),
            fnum(p.embed_secs),
            fnum(p.kmeans_secs),
            fnum(p.total_secs),
            format!("{:.3}", p.accuracy),
        ]);
    }
    out.push_str(&t.render());
    // linear-fit sanity line: total(N) / N should be ~constant
    if points.len() >= 2 {
        let first = points.first().unwrap();
        let last = points.last().unwrap();
        let ratio = (last.total_secs / last.n as f64) / (first.total_secs / first.n as f64);
        out.push_str(&format!(
            "per-point cost ratio (largest/smallest N): {ratio:.2} (≈1 ⇒ linear, ≫1 ⇒ superlinear)\n"
        ));
    }
    out
}

pub fn render_theory(points: &[TheoryPoint]) -> String {
    let mut out = String::from("== Theorem 2 empirics: objective gap vs R ==\n");
    let mut t = Table::new(vec!["R", "kappa", "gap f(Û)−f(U*)", "1/(κR) (theory slope)"]);
    for p in points {
        t.row(vec![
            p.r.to_string(),
            format!("{:.2}", p.kappa),
            format!("{:.3e}", p.gap),
            format!("{:.3e}", p.predicted_slope),
        ]);
    }
    out.push_str(&t.render());
    out
}

/// Dump a grid result to JSON (machine-readable record for EXPERIMENTS.md).
pub fn grid_to_json(grid: &GridResult) -> Json {
    let mut root = Json::obj();
    let mut rows = Vec::new();
    for row in &grid.datasets {
        let mut jrow = Json::obj();
        jrow.set("dataset", Json::Str(row.name.clone()));
        jrow.set("n", Json::Num(row.n as f64));
        let mut methods = Vec::new();
        for (i, r) in row.runs.iter().enumerate() {
            let mut jm = Json::obj();
            jm.set("method", Json::Str(MethodKind::ALL[i].name().into()));
            match r {
                Some(run) => {
                    jm.set("nmi", Json::Num(run.metrics.nmi));
                    jm.set("ri", Json::Num(run.metrics.rand_index));
                    jm.set("fm", Json::Num(run.metrics.f_measure));
                    jm.set("acc", Json::Num(run.metrics.accuracy));
                    jm.set("rank", Json::Num(row.ranks[i]));
                    jm.set("secs", Json::Num(run.secs));
                    jm.set("svd_matvecs", Json::Num(run.svd_matvecs as f64));
                }
                None => {
                    jm.set("skipped", Json::Bool(true));
                }
            }
            methods.push(jm);
        }
        jrow.set("methods", Json::Arr(methods));
        rows.push(jrow);
    }
    root.set("rows", Json::Arr(rows));
    root
}

/// Write a string to `results/<name>`, creating the directory.
pub fn save(name: &str, content: &str) -> std::io::Result<String> {
    std::fs::create_dir_all("results")?;
    let path = format!("results/{name}");
    std::fs::write(&path, content)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lists_all_benchmarks() {
        let t = render_table1(64);
        for spec in crate::data::PAPER_BENCHMARKS {
            assert!(t.contains(spec.name), "missing {}", spec.name);
        }
        assert!(t.contains("1025010"));
    }

    #[test]
    fn series_renders() {
        let s = vec![Series {
            label: "SC_RB".into(),
            points: vec![super::super::experiment::SeriesPoint { x: 16.0, acc: 0.5, secs: 1.0 }],
        }];
        let out = render_series("t", &s, "R");
        assert!(out.contains("SC_RB"));
        assert!(out.contains("16"));
    }
}
