//! Experiment coordinator (the L3 orchestration layer): owns the XLA
//! runtime, builds datasets, fans methods out over the comparison grid,
//! collects metrics + stage timings, and renders the paper's tables and
//! figures. Every driver in [`experiment`] maps 1:1 to a table/figure
//! (see DESIGN.md §6).

pub mod experiment;
pub mod report;

use crate::cluster::{Env, MethodKind};
use crate::config::{Engine, PipelineConfig};
use crate::data::Dataset;
use crate::error::ScrbError;
use crate::kernels::median_heuristic_sigma;
use crate::metrics::{all_metrics, ClusterMetrics};
use crate::pipeline::ArtifactCache;
use crate::runtime::XlaRuntime;
use std::cell::RefCell;
use std::time::Instant;

/// Shared context for experiment drivers.
pub struct Coordinator {
    pub base_cfg: PipelineConfig,
    /// Dataset size divisor (1 = full paper sizes).
    pub scale: usize,
    pub xla: Option<XlaRuntime>,
    pub verbose: bool,
    /// Stage-artifact cache shared by every run this coordinator drives:
    /// sweep drivers (σ/R/k/solver grids, the method comparison) reuse
    /// expensive upstream artifacts instead of recomputing them — e.g.
    /// the three RF-family methods share one RF featurization per
    /// dataset, and a solver sweep re-runs only the embed stage. Drivers
    /// clear it between datasets to bound resident memory.
    cache: RefCell<ArtifactCache>,
}

/// One method's outcome on one dataset.
#[derive(Clone, Debug)]
pub struct MethodRun {
    pub method: MethodKind,
    pub dataset: String,
    pub n: usize,
    pub r: usize,
    pub metrics: ClusterMetrics,
    pub secs: f64,
    /// (stage name, seconds) in execution order.
    pub stages: Vec<(String, f64)>,
    pub feature_dim: usize,
    pub svd_matvecs: usize,
    pub svd_converged: bool,
    pub kappa: Option<f64>,
}

impl Coordinator {
    /// Build a coordinator; tries to load the XLA runtime unless the
    /// engine is `native`.
    pub fn new(base_cfg: PipelineConfig, scale: usize) -> Coordinator {
        let xla = match base_cfg.engine {
            Engine::Native => None,
            Engine::Xla | Engine::Auto => match XlaRuntime::load(&base_cfg.artifacts_dir) {
                Ok(rt) => Some(rt),
                Err(e) => {
                    if base_cfg.engine == Engine::Xla {
                        panic!("--engine xla requested but runtime failed to load: {e:#}");
                    }
                    None
                }
            },
        };
        let verbose = base_cfg.verbose;
        Coordinator {
            base_cfg,
            scale,
            xla,
            verbose,
            cache: RefCell::new(ArtifactCache::new()),
        }
    }

    /// Pipeline config specialized to a dataset: K from the labels, σ
    /// selected once per dataset and shared by all methods (the paper's
    /// fairness protocol; it cross-validates σ in [0.01, 100] — we use an
    /// unsupervised analogue: the eigengap criterion over candidate
    /// multiples of the median-heuristic bandwidth) unless pinned via CLI.
    /// Derived through [`PipelineConfig::rebuild`], so the per-dataset
    /// config is re-validated rather than field-poked.
    pub fn cfg_for(&self, ds: &Dataset, sigma_override: Option<f64>) -> PipelineConfig {
        let k = ds.k.max(2);
        let with_k = self
            .base_cfg
            .rebuild(|b| {
                // a pinned embedding width can never be narrower than the
                // dataset-derived K: widen it instead of failing the sweep
                let b = match self.base_cfg.embed_dim {
                    Some(dim) if dim < k => b.embed_dim(k),
                    _ => b,
                };
                b.k(k)
            })
            .expect("dataset-derived cluster count must validate");
        let sigma = sigma_override.unwrap_or_else(|| select_sigma(&with_k, ds));
        with_k
            .rebuild(|b| b.sigma(sigma))
            .expect("selected bandwidth must be positive and finite")
    }

    /// Drop every cached stage artifact (drivers call this between
    /// datasets to bound resident memory).
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }

    /// Cache hit/miss counters of the coordinator's artifact cache.
    pub fn cache_stats(&self) -> (usize, usize) {
        let c = self.cache.borrow();
        (c.hits, c.misses)
    }

    /// Run one method on one dataset and score it. Drives the method's
    /// stage composition through the coordinator's artifact cache, so
    /// sweeps reuse unchanged upstream stages.
    pub fn run_method(
        &self,
        kind: MethodKind,
        ds: &Dataset,
        cfg: &PipelineConfig,
    ) -> Result<MethodRun, ScrbError> {
        let env = Env::with_xla(cfg.clone(), self.xla.as_ref());
        let t0 = Instant::now();
        let fitted =
            kind.pipeline(cfg).fit_cached(&env, &ds.x, &mut self.cache.borrow_mut())?;
        let out = fitted.result.output;
        // Cache-hit stages contribute their originally measured durations
        // to the output timer, so the reported time is the method's
        // *standalone* cost even when the sweep reused artifacts — the
        // paper's runtime figures must not depend on driver loop order.
        // Fully-cold runs report plain wall-clock (wall ≥ timer total).
        let secs = t0.elapsed().as_secs_f64().max(out.timer.total().as_secs_f64());
        let metrics = all_metrics(&out.labels, &ds.y);
        if self.verbose {
            eprintln!(
                "  {:<8} on {:<13} n={:<8} r={:<5} acc={:.3} nmi={:.3} {:.2}s [{}]",
                kind.name(),
                ds.name,
                ds.n(),
                cfg.r,
                metrics.accuracy,
                metrics.nmi,
                secs,
                out.timer.summary()
            );
        }
        Ok(MethodRun {
            method: kind,
            dataset: ds.name.clone(),
            n: ds.n(),
            r: cfg.r,
            metrics,
            secs,
            stages: out
                .timer
                .names()
                .iter()
                .map(|n| (n.clone(), out.timer.secs(n)))
                .collect(),
            feature_dim: out.info.feature_dim,
            svd_matvecs: out.info.svd.as_ref().map(|s| s.matvecs).unwrap_or(0),
            svd_converged: out.info.svd.as_ref().map(|s| s.converged).unwrap_or(true),
            kappa: out.info.kappa,
        })
    }

    /// Whether exact SC is feasible for this size (paper reports "−" above
    /// ~tens of thousands of points).
    pub fn exact_sc_feasible(&self, n: usize) -> bool {
        n <= crate::cluster::sc_exact::MAX_EXACT_N.min(20_000)
    }

    /// Fit SC_RB out-of-core from a LibSVM file: the coordinator's
    /// streaming entry point (`scrb fit --stream`). Unlike the in-memory
    /// drivers there is no data matrix to select σ on, so the bandwidth
    /// must be pinned (`sigma` here, `--sigma` at the CLI); K defaults to
    /// the stream's label census when not given (`opts.k`), mirroring
    /// [`Coordinator::cfg_for`]. All knobs are validated through the one
    /// [`PipelineConfig::validate`] routine (chunk/block rows, σ domain).
    /// `opts` also carries the fault policy and checkpoint configuration
    /// (see [`crate::stream::StreamOpts`]).
    pub fn fit_streaming(
        &self,
        path: &str,
        chunk_rows: usize,
        sigma: f64,
        opts: crate::stream::StreamOpts,
    ) -> Result<crate::stream::StreamFit, ScrbError> {
        let cfg = self.base_cfg.rebuild(|b| {
            let b = b.sigma(sigma).stream(chunk_rows, opts.block_rows);
            match opts.k {
                Some(k) => b.k(k),
                None => b,
            }
        })?;
        let env = Env::with_xla(cfg, self.xla.as_ref());
        let mut reader = crate::stream::LibsvmChunks::from_path(path, chunk_rows)?;
        crate::stream::fit_streaming(&env, &mut reader, &opts)
    }

    /// Sharded out-of-core SC_RB fit: plan `patterns` (file paths and/or
    /// `*`/`?` globs) into `shards` parallel row ranges, featurize them
    /// concurrently, and merge — bit-identical to [`Self::fit_streaming`]
    /// over the same bytes, for any shard count (see [`crate::shard`]).
    pub fn fit_streaming_sharded(
        &self,
        patterns: &[String],
        shards: usize,
        chunk_rows: usize,
        sigma: f64,
        opts: crate::stream::StreamOpts,
    ) -> Result<crate::stream::StreamFit, ScrbError> {
        let cfg = self.base_cfg.rebuild(|b| {
            let b = b.sigma(sigma).stream(chunk_rows, opts.block_rows).shards(shards);
            match opts.k {
                Some(k) => b.k(k),
                None => b,
            }
        })?;
        let env = Env::with_xla(cfg, self.xla.as_ref());
        let planner =
            crate::shard::ShardPlanner::new(shards, chunk_rows, crate::shard::ShardFormat::Libsvm);
        let plan = planner.plan(patterns)?;
        let mut readers = crate::shard::ShardPlanner::open(&plan)?;
        let mut refs: Vec<&mut (dyn crate::stream::ChunkReader + Send)> =
            readers.iter_mut().map(|r| r.as_mut()).collect();
        crate::stream::fit_streaming_sharded(&env, &mut refs, &opts)
    }
}

/// Unsupervised bandwidth selection: evaluate candidate σ = median·f on a
/// subsample by the eigengap λ_K − λ_{K+1} of the exact normalized
/// similarity — the classical "well-separated clusters ⇔ large Laplacian
/// eigengap" criterion (von Luxburg §8). Every method then shares the
/// winning σ, mirroring the paper's per-dataset cross-validated kernel.
pub fn select_sigma(cfg: &PipelineConfig, ds: &Dataset) -> f64 {
    let med = median_heuristic_sigma(cfg.kernel.name(), &ds.x, cfg.seed);
    let n_sub = 220.min(ds.n());
    if n_sub < 3 * cfg.k.max(2) {
        return med;
    }
    let mut rng = crate::util::rng::Pcg::new(cfg.seed, 0x516a);
    let idx = rng.sample_indices(ds.n(), n_sub);
    let xs = ds.x.select_rows(&idx);
    let k = ds.k.max(2).min(n_sub - 2);
    let mut best = (f64::NEG_INFINITY, med);
    for f in [0.125f64, 0.25, 0.5, 1.0] {
        let sigma = med * f;
        let w = crate::kernels::kernel_matrix(cfg.kernel.with_sigma(sigma), &xs);
        // normalized similarity S = D^{-1/2} W D^{-1/2}
        let mut s = w;
        let scale: Vec<f64> = (0..n_sub)
            .map(|i| 1.0 / s.row(i).iter().sum::<f64>().max(1e-300).sqrt())
            .collect();
        for i in 0..n_sub {
            for j in 0..n_sub {
                let v = scale[i] * s.at(i, j) * scale[j];
                s.set(i, j, v);
            }
        }
        let eig = crate::linalg::sym_eig(&s);
        // eigenvalues ascending; top-K gap:
        let lam = &eig.w;
        let m = lam.len();
        let gap = lam[m - k] - lam[m - k - 1];
        if gap > best.0 {
            best = (gap, sigma);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn coordinator_runs_a_method() {
        let cfg = PipelineConfig::builder()
            .engine(Engine::Native)
            .r(64)
            .kmeans_replicates(2)
            .build();
        let coord = Coordinator::new(cfg, 1);
        let ds = synth::gaussian_blobs(200, 3, 3, 8.0, 3);
        let dcfg = coord.cfg_for(&ds, None);
        assert_eq!(dcfg.k, 3);
        assert!(dcfg.kernel.sigma() > 0.0);
        let run = coord.run_method(MethodKind::ScRb, &ds, &dcfg).unwrap();
        assert_eq!(run.n, 200);
        assert!(run.metrics.accuracy > 0.5);
        assert!(run.secs > 0.0);
        assert!(!run.stages.is_empty());
    }

    #[test]
    fn exact_feasibility_gate() {
        let cfg = PipelineConfig { engine: Engine::Native, ..Default::default() };
        let coord = Coordinator::new(cfg, 1);
        assert!(coord.exact_sc_feasible(5_000));
        assert!(!coord.exact_sc_feasible(100_000));
    }
}
