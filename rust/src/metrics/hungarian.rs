//! Hungarian (Kuhn–Munkres) algorithm, O(n³), for the optimal label
//! mapping in the Accuracy metric (the paper's best mapping function δ).

/// Maximum-weight perfect matching on a square `n×n` profit matrix.
/// Returns `assign[row] = col`.
pub fn max_assignment(profit: &[Vec<f64>]) -> Vec<usize> {
    let n = profit.len();
    if n == 0 {
        return vec![];
    }
    for row in profit {
        assert_eq!(row.len(), n, "profit matrix must be square");
    }
    // Convert to min-cost with non-negative entries.
    let maxv = profit.iter().flat_map(|r| r.iter()).cloned().fold(f64::MIN, f64::max);
    let cost: Vec<Vec<f64>> = profit.iter().map(|r| r.iter().map(|&v| maxv - v).collect()).collect();
    min_cost_assignment(&cost)
}

/// Minimum-cost perfect matching (Jonker-style potentials formulation of
/// the Hungarian algorithm). `assign[row] = col`.
pub fn min_cost_assignment(cost: &[Vec<f64>]) -> Vec<usize> {
    let n = cost.len();
    // potentials and matching arrays are 1-indexed internally
    let inf = f64::INFINITY;
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[col] = row matched to col (0 = none)
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut assign = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            assign[p[j] - 1] = j - 1;
        }
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_profit() {
        let profit = vec![
            vec![10.0, 0.0, 0.0],
            vec![0.0, 10.0, 0.0],
            vec![0.0, 0.0, 10.0],
        ];
        assert_eq!(max_assignment(&profit), vec![0, 1, 2]);
    }

    #[test]
    fn permuted_profit() {
        let profit = vec![
            vec![0.0, 5.0, 1.0],
            vec![7.0, 0.0, 0.0],
            vec![0.0, 1.0, 9.0],
        ];
        assert_eq!(max_assignment(&profit), vec![1, 0, 2]);
    }

    #[test]
    fn classic_min_cost() {
        // classic example: optimal cost 5 (0->1, 1->0, 2->2)
        let cost = vec![
            vec![4.0, 1.0, 3.0],
            vec![2.0, 0.0, 5.0],
            vec![3.0, 2.0, 2.0],
        ];
        let a = min_cost_assignment(&cost);
        let total: f64 = a.iter().enumerate().map(|(i, &j)| cost[i][j]).sum();
        assert_eq!(total, 5.0);
    }

    #[test]
    fn brute_force_agreement_small() {
        // compare against brute force over all permutations, n=4
        let cost = vec![
            vec![9.0, 2.0, 7.0, 8.0],
            vec![6.0, 4.0, 3.0, 7.0],
            vec![5.0, 8.0, 1.0, 8.0],
            vec![7.0, 6.0, 9.0, 4.0],
        ];
        let a = min_cost_assignment(&cost);
        let total: f64 = a.iter().enumerate().map(|(i, &j)| cost[i][j]).sum();
        // brute force
        let mut best = f64::INFINITY;
        let mut perm = [0usize, 1, 2, 3];
        permute(&mut perm, 0, &mut |p| {
            let c: f64 = p.iter().enumerate().map(|(i, &j)| cost[i][j]).sum();
            if c < best {
                best = c;
            }
        });
        assert_eq!(total, best);
    }

    fn permute(arr: &mut [usize; 4], k: usize, f: &mut impl FnMut(&[usize; 4])) {
        if k == 4 {
            f(arr);
            return;
        }
        for i in k..4 {
            arr.swap(k, i);
            permute(arr, k + 1, f);
            arr.swap(k, i);
        }
    }
}
