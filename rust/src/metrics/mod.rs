//! Clustering quality metrics (§5 of the paper): NMI, Rand Index,
//! F-measure, and Accuracy under the optimal (Hungarian) label mapping,
//! plus the average-rank aggregation of Yang & Leskovec used in Table 2.

pub mod hungarian;

use hungarian::max_assignment;
use std::collections::BTreeSet;

/// All four paper metrics for one clustering.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClusterMetrics {
    pub nmi: f64,
    pub rand_index: f64,
    pub f_measure: f64,
    pub accuracy: f64,
}

impl ClusterMetrics {
    pub fn as_array(&self) -> [f64; 4] {
        [self.nmi, self.rand_index, self.f_measure, self.accuracy]
    }

    pub const NAMES: [&'static str; 4] = ["NMI", "RI", "FM", "Acc"];
}

/// Contingency table between predicted and true labels (labels may be any
/// usize values; they are compacted first).
struct Contingency {
    /// counts[a][b] = |{i : pred_i = a, true_i = b}|
    counts: Vec<Vec<usize>>,
    pred_sizes: Vec<usize>,
    true_sizes: Vec<usize>,
    n: usize,
}

fn compact(labels: &[usize]) -> (Vec<usize>, usize) {
    let uniq: BTreeSet<usize> = labels.iter().copied().collect();
    let map: std::collections::BTreeMap<usize, usize> =
        uniq.iter().enumerate().map(|(i, &v)| (v, i)).collect();
    (labels.iter().map(|v| map[v]).collect(), uniq.len())
}

fn contingency(pred: &[usize], truth: &[usize]) -> Contingency {
    assert_eq!(pred.len(), truth.len(), "label length mismatch");
    let n = pred.len();
    let (p, kp) = compact(pred);
    let (t, kt) = compact(truth);
    let mut counts = vec![vec![0usize; kt]; kp];
    let mut pred_sizes = vec![0usize; kp];
    let mut true_sizes = vec![0usize; kt];
    for i in 0..n {
        counts[p[i]][t[i]] += 1;
        pred_sizes[p[i]] += 1;
        true_sizes[t[i]] += 1;
    }
    Contingency { counts, pred_sizes, true_sizes, n }
}

fn entropy(sizes: &[usize], n: usize) -> f64 {
    let nf = n as f64;
    sizes
        .iter()
        .filter(|&&s| s > 0)
        .map(|&s| {
            let p = s as f64 / nf;
            -p * p.ln()
        })
        .sum()
}

/// Normalized Mutual Information: 2·I(C, C′)/(H(C)+H(C′)).
pub fn nmi(pred: &[usize], truth: &[usize]) -> f64 {
    let ct = contingency(pred, truth);
    let nf = ct.n as f64;
    let mut mi = 0.0;
    for (a, row) in ct.counts.iter().enumerate() {
        for (b, &c) in row.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let pab = c as f64 / nf;
            let pa = ct.pred_sizes[a] as f64 / nf;
            let pb = ct.true_sizes[b] as f64 / nf;
            mi += pab * (pab / (pa * pb)).ln();
        }
    }
    let h = entropy(&ct.pred_sizes, ct.n) + entropy(&ct.true_sizes, ct.n);
    if h <= 0.0 {
        // both clusterings are single-cluster: identical by convention
        1.0
    } else {
        (2.0 * mi / h).clamp(0.0, 1.0)
    }
}

/// Rand Index: (TP+TN) / #pairs, over all C(n,2) point pairs.
pub fn rand_index(pred: &[usize], truth: &[usize]) -> f64 {
    let ct = contingency(pred, truth);
    let n = ct.n;
    if n < 2 {
        return 1.0;
    }
    let c2 = |x: usize| (x * x.saturating_sub(1)) / 2;
    let pairs = c2(n);
    let same_both: usize = ct.counts.iter().flat_map(|r| r.iter()).map(|&c| c2(c)).sum();
    let same_pred: usize = ct.pred_sizes.iter().map(|&s| c2(s)).sum();
    let same_true: usize = ct.true_sizes.iter().map(|&s| c2(s)).sum();
    // TP = same_both; FP = same_pred − TP; FN = same_true − TP;
    // TN = pairs − TP − FP − FN.
    let tp = same_both;
    let fp = same_pred - tp;
    let fnn = same_true - tp;
    let tn = pairs - tp - fp - fnn;
    (tp + tn) as f64 / pairs as f64
}

/// F-measure: mean over predicted clusters of the harmonic mean of
/// precision/recall against each cluster's best-matching true class.
pub fn f_measure(pred: &[usize], truth: &[usize]) -> f64 {
    let ct = contingency(pred, truth);
    if ct.counts.is_empty() {
        return 0.0;
    }
    let mut total = 0.0;
    for (a, row) in ct.counts.iter().enumerate() {
        let mut best = 0.0f64;
        for (b, &c) in row.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let prec = c as f64 / ct.pred_sizes[a] as f64;
            let rec = c as f64 / ct.true_sizes[b] as f64;
            let f = 2.0 * prec * rec / (prec + rec);
            best = best.max(f);
        }
        total += best;
    }
    total / ct.counts.len() as f64
}

/// Accuracy: fraction of points whose predicted label equals the true
/// label under the optimal one-to-one mapping (Hungarian on the padded
/// contingency table).
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    let ct = contingency(pred, truth);
    let k = ct.counts.len().max(ct.true_sizes.len());
    // padded square profit matrix
    let mut profit = vec![vec![0.0f64; k]; k];
    for (a, row) in ct.counts.iter().enumerate() {
        for (b, &c) in row.iter().enumerate() {
            profit[a][b] = c as f64;
        }
    }
    let assign = max_assignment(&profit);
    let matched: f64 = assign
        .iter()
        .enumerate()
        .map(|(a, &b)| if a < ct.counts.len() && b < ct.true_sizes.len() {
            ct.counts[a][b] as f64
        } else {
            0.0
        })
        .sum();
    matched / ct.n as f64
}

/// All four metrics at once.
pub fn all_metrics(pred: &[usize], truth: &[usize]) -> ClusterMetrics {
    ClusterMetrics {
        nmi: nmi(pred, truth),
        rand_index: rand_index(pred, truth),
        f_measure: f_measure(pred, truth),
        accuracy: accuracy(pred, truth),
    }
}

/// Average-rank aggregation (Yang & Leskovec 2015, as used for Table 2):
/// for each metric, rank the methods (1 = best, ties share the mean rank),
/// then average each method's ranks across metrics. Lower is better.
/// `scores[m]` holds method m's metric array; NaN = method did not run
/// (ranked last).
pub fn average_rank_scores(scores: &[ClusterMetrics]) -> Vec<f64> {
    let n = scores.len();
    let mut rank_sum = vec![0.0f64; n];
    for metric_idx in 0..4 {
        let vals: Vec<f64> = scores.iter().map(|s| s.as_array()[metric_idx]).collect();
        let ranks = rank_descending(&vals);
        for (r, acc) in ranks.iter().zip(rank_sum.iter_mut()) {
            *acc += *r;
        }
    }
    rank_sum.iter().map(|s| s / 4.0).collect()
}

/// Ranks with 1 = largest value; ties get the mean of their positions;
/// NaN ranks after everything.
pub fn rank_descending(vals: &[f64]) -> Vec<f64> {
    let n = vals.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        let va = vals[a];
        let vb = vals[b];
        match (va.is_nan(), vb.is_nan()) {
            (true, true) => std::cmp::Ordering::Equal,
            (true, false) => std::cmp::Ordering::Greater,
            (false, true) => std::cmp::Ordering::Less,
            _ => vb.partial_cmp(&va).unwrap(),
        }
    });
    let mut ranks = vec![0.0; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        let vi = vals[idx[i]];
        while j + 1 < n && (vals[idx[j + 1]] == vi || (vals[idx[j + 1]].is_nan() && vi.is_nan())) {
            j += 1;
        }
        let mean_rank = (i + j) as f64 / 2.0 + 1.0;
        for &slot in idx.iter().take(j + 1).skip(i) {
            ranks[slot] = mean_rank;
        }
        i = j + 1;
    }
    ranks
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_clustering_scores_one() {
        let y = vec![0, 0, 1, 1, 2, 2];
        let m = all_metrics(&y, &y);
        assert!((m.nmi - 1.0).abs() < 1e-12);
        assert!((m.rand_index - 1.0).abs() < 1e-12);
        assert!((m.f_measure - 1.0).abs() < 1e-12);
        assert!((m.accuracy - 1.0).abs() < 1e-12);
    }

    #[test]
    fn permutation_invariant() {
        let truth = vec![0, 0, 1, 1, 2, 2];
        let pred = vec![2, 2, 0, 0, 1, 1]; // same partition, renamed
        let m = all_metrics(&pred, &truth);
        assert!((m.accuracy - 1.0).abs() < 1e-12);
        assert!((m.nmi - 1.0).abs() < 1e-12);
    }

    #[test]
    fn random_clustering_scores_low() {
        // deterministic "random" labels
        let truth: Vec<usize> = (0..400).map(|i| i % 4).collect();
        let pred: Vec<usize> = (0..400).map(|i| (i * 7 + 3) % 5 % 4).collect();
        let m = all_metrics(&pred, &truth);
        assert!(m.nmi < 0.2, "nmi {}", m.nmi);
        assert!(m.accuracy < 0.5, "acc {}", m.accuracy);
    }

    #[test]
    fn accuracy_known_example() {
        // pred cluster 0 = {0,1,2}, truth = {0,1},{2,3}: best map gives 3/4?
        let truth = vec![0, 0, 1, 1];
        let pred = vec![0, 0, 0, 1];
        // optimal: 0->0 (2 hits), 1->1 (1 hit) = 3/4
        assert!((accuracy(&pred, &truth) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn rand_index_known_example() {
        let truth = vec![0, 0, 1, 1];
        let pred = vec![0, 1, 0, 1];
        // pairs: 6; TP=0; same_pred=2, same_true=2 → FP=2, FN=2, TN=2 → RI=2/6
        assert!((rand_index(&pred, &truth) - 2.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn nmi_independent_is_zero() {
        // truth splits first/second half; pred splits even/odd — independent
        let truth: Vec<usize> = (0..1000).map(|i| i / 500).collect();
        let pred: Vec<usize> = (0..1000).map(|i| i % 2).collect();
        assert!(nmi(&pred, &truth) < 1e-10);
    }

    #[test]
    fn more_clusters_than_truth_handled() {
        let truth = vec![0, 0, 0, 1, 1, 1];
        let pred = vec![0, 1, 2, 3, 4, 5]; // singletons
        let m = all_metrics(&pred, &truth);
        assert!(m.accuracy <= 2.0 / 6.0 + 1e-12);
        assert!(m.f_measure < 0.6);
    }

    #[test]
    fn rank_aggregation() {
        let a = ClusterMetrics { nmi: 0.9, rand_index: 0.9, f_measure: 0.9, accuracy: 0.9 };
        let b = ClusterMetrics { nmi: 0.5, rand_index: 0.5, f_measure: 0.5, accuracy: 0.5 };
        let c = ClusterMetrics { nmi: 0.5, rand_index: 0.5, f_measure: 0.5, accuracy: 0.5 };
        let ranks = average_rank_scores(&[a, b, c]);
        assert_eq!(ranks[0], 1.0);
        assert_eq!(ranks[1], 2.5); // tie between b and c
        assert_eq!(ranks[2], 2.5);
    }

    #[test]
    fn rank_nan_last() {
        let ranks = rank_descending(&[0.5, f64::NAN, 0.9]);
        assert_eq!(ranks, vec![2.0, 3.0, 1.0]);
    }
}
