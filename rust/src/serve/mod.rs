//! Resilient clustering-as-a-service: the `scrb serve` daemon.
//!
//! Serves a fitted [`ScRbModel`] over TCP with a checksummed,
//! length-prefixed binary protocol ([`protocol`]) — std-only, no async
//! runtime, built on `std::net::TcpListener` and plain threads like the
//! rest of the crate's parallelism ([`crate::util::threads`]).
//!
//! The resilience contract, piece by piece:
//!
//! - **Bounded admission + load shedding** ([`queue`]): a full queue
//!   rejects with a typed [`ErrorCode::Overloaded`] instead of queueing
//!   unboundedly or blocking the reader — under overload the daemon
//!   degrades by saying "no" quickly, never by falling over.
//! - **Micro-batching** ([`server`]): workers coalesce up to
//!   `max_batch` queued requests into one [`FittedModel::predict_batch`]
//!   call over a reused [`ServeWorkspace`] — zero steady-state
//!   allocations in the hot path, and row-independent serving means the
//!   coalesced labels are bit-equal to per-request predictions.
//! - **Per-request deadlines**: a request that waits past its deadline
//!   is answered [`ErrorCode::Timeout`] rather than served stale.
//! - **Typed protocol errors**: malformed, truncated, or oversized
//!   frames get [`ErrorCode`] responses, not dropped connections; an
//!   oversized payload is discarded in bounded chunks and the
//!   connection survives.
//! - **Worker panic isolation**: a panicking worker is caught,
//!   restarted with fresh scratch, and the poisoned batch answered with
//!   [`ErrorCode::Internal`]; other in-flight requests are unaffected.
//! - **Hot model swap with rollback** ([`swap`]): a swap validates the
//!   candidate through the checksummed loader *and* a self-check
//!   prediction before atomically publishing; any failure keeps the old
//!   model. Workers pin the model `Arc` once per batch, so no request
//!   is ever served by two versions.
//! - **Graceful drain**: a `Drain` frame or SIGTERM
//!   ([`install_sigterm_drain`]) stops admission, finishes every queued
//!   request, and exits.
//!
//! Observability: a `Status` frame returns a JSON document with queue
//! depth, shed/timeout/restart counters, drift statistics
//! ([`crate::model::DriftStats`]), and the swap audit trail.
//!
//! [`FittedModel::predict_batch`]: crate::model::FittedModel::predict_batch
//! [`ServeWorkspace`]: crate::model::ServeWorkspace
//! [`ErrorCode`]: protocol::ErrorCode
//! [`ErrorCode::Overloaded`]: protocol::ErrorCode::Overloaded
//! [`ErrorCode::Timeout`]: protocol::ErrorCode::Timeout
//! [`ErrorCode::Internal`]: protocol::ErrorCode::Internal

pub mod client;
pub mod protocol;
mod queue;
pub mod server;
mod swap;

pub use client::{ServeClient, ServeError};
pub use protocol::{ErrorCode, Frame, FrameKind};
pub use server::{install_sigterm_drain, ServeConfig, Server, ServerHandle};
pub use swap::SwapRecord;

use crate::model::ScRbModel;

/// Build a tiny but fully serviceable [`ScRbModel`] (real codebook over
/// random data, arbitrary projection/centroids) with `d_in = 3`.
/// Support code for this crate's serve tests and benches — not part of
/// the public API surface.
#[doc(hidden)]
pub fn test_model(n: usize, r: usize, k: usize, seed: u64) -> ScRbModel {
    test_model_dim(n, r, k, 3, seed)
}

/// [`test_model`] with an explicit input dimensionality.
#[doc(hidden)]
pub fn test_model_dim(n: usize, r: usize, k: usize, d_in: usize, seed: u64) -> ScRbModel {
    use crate::config::Kernel;
    use crate::linalg::Mat;
    use crate::model::{DriftMonitor, DEFAULT_UNSEEN_WARN};
    use crate::rb::rb_features_with_codebook;
    use crate::util::rng::Pcg;
    let mut rng = Pcg::seed(seed);
    let x = Mat::from_vec(n, d_in, (0..n * d_in).map(|_| rng.f64()).collect());
    let (rb, codebook) = rb_features_with_codebook(&x, r, 0.5, seed ^ 0xab);
    let dim = rb.dim();
    let proj = Mat::from_vec(dim, k, (0..dim * k).map(|_| rng.range_f64(-1.0, 1.0)).collect());
    let centroids = Mat::from_vec(2, k, (0..2 * k).map(|_| rng.range_f64(-1.0, 1.0)).collect());
    ScRbModel {
        codebook,
        kernel: Kernel::Laplacian { sigma: 0.5 },
        s: (0..k).map(|j| 1.0 / (j + 1) as f64).collect(),
        proj,
        centroids,
        norm: None,
        drift: DriftMonitor::default(),
        unseen_warn: DEFAULT_UNSEEN_WARN,
        update_state: Default::default(),
    }
}
