//! Blocking client for the serving daemon.
//!
//! One connection, synchronous request/response. Server-side rejections
//! arrive as [`ServeError::Rejected`] carrying the typed
//! [`ErrorCode`], so callers can branch on *why* (retry `Overloaded`,
//! fix the batch on `Malformed`, give up on `Draining`) without parsing
//! message text. Transport failures map to [`ServeError::Transport`].

use super::protocol::{
    decode_error, decode_labels, encode_frame, encode_predict, encode_swap, read_frame_blocking,
    ErrorCode, Frame, FrameKind, DEFAULT_MAX_FRAME,
};
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::util::json::Json;
use std::io::Write;
use std::net::TcpStream;

/// Why a client call failed.
#[derive(Debug)]
pub enum ServeError {
    /// The connection or the protocol broke.
    Transport(ScrbError),
    /// The daemon answered with a typed rejection.
    Rejected { code: ErrorCode, message: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Transport(e) => write!(f, "{e}"),
            ServeError::Rejected { code, message } => {
                write!(f, "rejected ({}): {message}", code.as_str())
            }
        }
    }
}

impl std::error::Error for ServeError {}

impl From<ServeError> for ScrbError {
    fn from(e: ServeError) -> ScrbError {
        match e {
            ServeError::Transport(inner) => inner,
            ServeError::Rejected { code, message } => {
                ScrbError::serve(format!("{}: {message}", code.as_str()))
            }
        }
    }
}

fn transport(msg: impl Into<String>) -> ServeError {
    ServeError::Transport(ScrbError::serve(msg))
}

/// A blocking connection to a `scrb serve` daemon.
pub struct ServeClient {
    stream: TcpStream,
    next_id: u64,
}

impl ServeClient {
    /// Connect to `addr` (e.g. `"127.0.0.1:7878"`).
    pub fn connect(addr: &str) -> Result<ServeClient, ScrbError> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| ScrbError::serve(format!("cannot connect to {addr}: {e}")))?;
        stream.set_nodelay(true).ok();
        Ok(ServeClient { stream, next_id: 1 })
    }

    fn roundtrip(&mut self, kind: FrameKind, payload: &[u8]) -> Result<Frame, ServeError> {
        let id = self.next_id;
        self.next_id += 1;
        let bytes = encode_frame(kind, id, payload);
        self.stream
            .write_all(&bytes)
            .map_err(|e| transport(format!("send failed: {e}")))?;
        let frame = read_frame_blocking(&mut self.stream, DEFAULT_MAX_FRAME)
            .map_err(ServeError::Transport)?;
        if frame.kind == FrameKind::Error {
            let (code, message) = decode_error(&frame.payload)
                .map_err(|m| transport(format!("undecodable error frame: {m}")))?;
            return Err(ServeError::Rejected { code, message });
        }
        if frame.req_id != id {
            return Err(transport(format!(
                "response id {} does not match request id {id}",
                frame.req_id
            )));
        }
        Ok(frame)
    }

    fn expect(frame: Frame, want: FrameKind) -> Result<Frame, ServeError> {
        if frame.kind != want {
            return Err(transport(format!("expected {want:?} response, got {:?}", frame.kind)));
        }
        Ok(frame)
    }

    /// Liveness probe.
    pub fn ping(&mut self) -> Result<(), ServeError> {
        Self::expect(self.roundtrip(FrameKind::Ping, &[])?, FrameKind::Pong).map(|_| ())
    }

    /// Label a batch under the server's default deadline. Returns
    /// `(model_version, labels)` — the version identifies exactly which
    /// model produced the labels (stable across hot swaps mid-call).
    pub fn predict(&mut self, x: &Mat) -> Result<(u32, Vec<usize>), ServeError> {
        self.predict_deadline(x, 0)
    }

    /// Label a batch with an explicit deadline in milliseconds
    /// (`0` = server default).
    pub fn predict_deadline(
        &mut self,
        x: &Mat,
        deadline_ms: u32,
    ) -> Result<(u32, Vec<usize>), ServeError> {
        let frame = Self::expect(
            self.roundtrip(FrameKind::Predict, &encode_predict(deadline_ms, x))?,
            FrameKind::Labels,
        )?;
        let (version, labels) = decode_labels(&frame.payload)
            .map_err(|m| transport(format!("undecodable labels frame: {m}")))?;
        if labels.len() != x.rows {
            return Err(transport(format!(
                "server answered {} labels for {} rows",
                labels.len(),
                x.rows
            )));
        }
        Ok((version, labels))
    }

    /// Fetch the daemon's STATUS document.
    pub fn status(&mut self) -> Result<Json, ServeError> {
        let frame =
            Self::expect(self.roundtrip(FrameKind::Status, &[])?, FrameKind::StatusReply)?;
        let text = std::str::from_utf8(&frame.payload)
            .map_err(|_| transport("non-UTF-8 status payload"))?;
        Json::parse(text).map_err(|m| transport(format!("bad status JSON: {m}")))
    }

    /// Ask the daemon to hot-swap to the model file at `path`; returns
    /// the new model version.
    pub fn swap(&mut self, path: &str) -> Result<u32, ServeError> {
        let frame =
            Self::expect(self.roundtrip(FrameKind::Swap, &encode_swap(path))?, FrameKind::SwapOk)?;
        if frame.payload.len() != 4 {
            return Err(transport("bad SwapOk payload"));
        }
        Ok(u32::from_le_bytes(frame.payload[..4].try_into().unwrap()))
    }

    /// Begin a graceful drain: the daemon finishes in-flight work and
    /// exits; new predictions are rejected with `Draining`.
    pub fn drain(&mut self) -> Result<(), ServeError> {
        Self::expect(self.roundtrip(FrameKind::Drain, &[])?, FrameKind::DrainOk).map(|_| ())
    }

    /// Send raw bytes on the connection (fault-injection tests: torn
    /// frames, garbage, oversized headers).
    pub fn send_raw(&mut self, bytes: &[u8]) -> Result<(), ScrbError> {
        self.stream
            .write_all(bytes)
            .map_err(|e| ScrbError::serve(format!("raw send failed: {e}")))
    }

    /// Read one raw response frame (pairs with [`ServeClient::send_raw`]).
    pub fn read_raw(&mut self) -> Result<Frame, ScrbError> {
        read_frame_blocking(&mut self.stream, DEFAULT_MAX_FRAME)
    }
}
