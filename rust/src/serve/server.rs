//! The serving daemon: acceptor, per-connection readers, and the
//! micro-batching worker pool.
//!
//! Thread shape (all `std::thread`, no async runtime):
//!
//! ```text
//!   run() thread ── accept loop ──┬── reader thread per connection
//!                                 │     parse frames, answer control,
//!                                 │     admit Predicts (or shed)
//!                                 │
//!   worker threads (cfg.workers) ─┴── pop_batch → coalesce → predict
//! ```
//!
//! Readers poll with a 50 ms socket timeout so they observe drain and
//! torn frames without extra machinery; workers wait on the queue's
//! condvar. A worker pins the model `Arc` once per batch, so a hot swap
//! never changes the model under an in-flight request. Worker panics are
//! contained with `catch_unwind`: the batch's requests are answered with
//! a typed `Internal` rejection, the worker rebuilds its scratch state
//! ("restarts") and keeps serving — one poisoned request cannot take the
//! daemon down.

use super::protocol::{
    encode_error, encode_labels, parse_header, ErrorCode, Frame, FrameKind, Header, HEADER_LEN,
};
use super::queue::{AdmitQueue, Conn, PendingRequest};
use super::swap::{ModelSlot, VersionedModel};
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::{FittedModel, ScRbModel, ServeWorkspace};
use crate::stream::fault::ServeFaultPlan;
use crate::util::json::Json;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Serving daemon configuration. Defaults favor a small test footprint;
/// the CLI exposes each knob.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address, e.g. `"127.0.0.1:7878"` (`:0` picks a free port).
    pub addr: String,
    /// Worker (micro-batcher) threads.
    pub workers: usize,
    /// Admission queue capacity; requests beyond it are shed.
    pub queue_cap: usize,
    /// Max requests coalesced into one `predict_batch` call.
    pub max_batch: usize,
    /// Deadline applied to requests that do not carry their own, in ms.
    pub default_deadline_ms: u64,
    /// Per-frame payload cap in bytes.
    pub max_frame_bytes: usize,
    /// How long a started frame may stall mid-read before it is declared
    /// torn and the connection closed with a typed error, in ms.
    pub frame_stall_ms: u64,
    /// Seeded fault injection (tests/benches only; default: no faults).
    pub fault: ServeFaultPlan,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            workers: 2,
            queue_cap: 256,
            max_batch: 64,
            default_deadline_ms: 1000,
            max_frame_bytes: super::protocol::DEFAULT_MAX_FRAME,
            frame_stall_ms: 5000,
            fault: ServeFaultPlan::default(),
        }
    }
}

/// Monotonic counters surfaced by `STATUS`. Relaxed atomics: statistics,
/// not synchronization — except where tests assert exactness, which
/// holds because each event increments exactly one site.
#[derive(Default)]
pub(crate) struct Counters {
    pub connections: AtomicU64,
    pub served_requests: AtomicU64,
    pub served_points: AtomicU64,
    pub batches: AtomicU64,
    pub shed: AtomicU64,
    pub timeouts: AtomicU64,
    pub restarts: AtomicU64,
    pub protocol_errors: AtomicU64,
    pub internal_rejects: AtomicU64,
    pub drain_rejects: AtomicU64,
    pub swaps_ok: AtomicU64,
    pub swaps_failed: AtomicU64,
}

pub(crate) struct Shared {
    pub cfg: ServeConfig,
    pub slot: ModelSlot,
    pub queue: AdmitQueue,
    pub stats: Counters,
    pub draining: AtomicBool,
    pub readers_active: AtomicUsize,
}

/// Process-wide SIGTERM latch (see [`install_sigterm_drain`]).
static SIGTERM_SEEN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" {
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
}

#[cfg(unix)]
extern "C" fn on_sigterm(_sig: i32) {
    // async-signal-safe: one atomic store, nothing else
    SIGTERM_SEEN.store(true, Ordering::SeqCst);
}

/// Route SIGTERM into a graceful drain: the acceptor stops admitting,
/// in-flight and queued requests finish, workers exit. Installed by the
/// CLI entry point; library users typically drive drain via the protocol
/// instead.
#[cfg(unix)]
pub fn install_sigterm_drain() {
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_sigterm);
    }
}

#[cfg(not(unix))]
pub fn install_sigterm_drain() {}

/// A bound (not yet running) serving daemon.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
}

/// Handle to a daemon running on a background thread.
pub struct ServerHandle {
    addr: SocketAddr,
    join: JoinHandle<Result<(), ScrbError>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wait for the daemon to drain and exit.
    pub fn join(self) -> Result<(), ScrbError> {
        self.join
            .join()
            .unwrap_or_else(|_| Err(ScrbError::serve("server thread panicked")))
    }
}

impl Server {
    /// Bind `cfg.addr` and install `model` as version 1.
    pub fn bind(cfg: ServeConfig, model: ScRbModel) -> Result<Server, ScrbError> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| ScrbError::serve(format!("cannot bind {}: {e}", cfg.addr)))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| ScrbError::serve(format!("cannot set nonblocking: {e}")))?;
        let queue = AdmitQueue::new(cfg.queue_cap);
        let shared = Arc::new(Shared {
            cfg,
            slot: ModelSlot::new(model),
            queue,
            stats: Counters::default(),
            draining: AtomicBool::new(false),
            readers_active: AtomicUsize::new(0),
        });
        Ok(Server { listener, shared })
    }

    /// The bound address (useful with `:0`).
    pub fn local_addr(&self) -> Result<SocketAddr, ScrbError> {
        self.listener
            .local_addr()
            .map_err(|e| ScrbError::serve(format!("cannot read local addr: {e}")))
    }

    /// Run the daemon on a background thread.
    pub fn spawn(self) -> Result<ServerHandle, ScrbError> {
        let addr = self.local_addr()?;
        let join = std::thread::spawn(move || self.run());
        Ok(ServerHandle { addr, join })
    }

    /// Run the daemon on the calling thread until a drain (protocol
    /// `Drain` frame or SIGTERM) completes: every admitted request is
    /// answered before this returns.
    pub fn run(self) -> Result<(), ScrbError> {
        let shared = self.shared;
        let workers: Vec<JoinHandle<()>> = (0..shared.cfg.workers.max(1))
            .map(|_| {
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(sh))
            })
            .collect();

        let mut readers: Vec<JoinHandle<()>> = Vec::new();
        loop {
            if SIGTERM_SEEN.load(Ordering::SeqCst) {
                shared.draining.store(true, Ordering::SeqCst);
            }
            if shared.draining.load(Ordering::SeqCst) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    shared.stats.connections.fetch_add(1, Ordering::Relaxed);
                    shared.readers_active.fetch_add(1, Ordering::SeqCst);
                    let sh = shared.clone();
                    readers.push(std::thread::spawn(move || {
                        reader_loop(&sh, stream);
                        sh.readers_active.fetch_sub(1, Ordering::SeqCst);
                        sh.queue.wake_all();
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => {
                    std::thread::sleep(Duration::from_millis(10));
                }
            }
        }
        // drain: readers notice the flag at their next idle tick and
        // exit; only then can no new request be admitted, so workers
        // wait for readers_active == 0 *and* an empty queue
        shared.queue.wake_all();
        for r in readers {
            let _ = r.join();
        }
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Connection reader
// ---------------------------------------------------------------------

/// What one poll of the socket produced.
enum ReadEvent {
    Frame(Frame),
    /// No byte arrived within the poll tick.
    Idle,
    /// Clean EOF at a frame boundary.
    Closed,
    /// Protocol violation: answer `code`, then close iff `fatal`.
    Bad { code: ErrorCode, msg: String, fatal: bool },
    /// Transport failure: close silently.
    Dead,
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Fill `buf` from a socket with a read timeout installed. `started`
/// says whether the frame already has bytes on the floor (an initial
/// quiet tick is `Idle`; a mid-frame stall longer than `stall` is torn).
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    mut started: bool,
    stall: Duration,
) -> Result<(), ReadEvent> {
    let mut filled = 0usize;
    let begin = Instant::now();
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(if started { torn("peer closed mid-frame") } else { ReadEvent::Closed })
            }
            Ok(n) => {
                filled += n;
                started = true;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => {
                if !started {
                    return Err(ReadEvent::Idle);
                }
                if begin.elapsed() > stall {
                    return Err(torn("frame stalled (peer too slow or died mid-write)"));
                }
            }
            Err(_) => return Err(ReadEvent::Dead),
        }
    }
    Ok(())
}

fn torn(msg: &str) -> ReadEvent {
    ReadEvent::Bad { code: ErrorCode::Malformed, msg: msg.to_string(), fatal: true }
}

/// Read one frame (or an event) from the socket.
fn read_event(stream: &mut TcpStream, max_frame: usize, stall: Duration) -> ReadEvent {
    let mut h = [0u8; HEADER_LEN];
    if let Err(ev) = read_full(stream, &mut h, false, stall) {
        return ev;
    }
    let Header { kind, req_id, len, payload_fnv } = match parse_header(&h) {
        Ok(hd) => hd,
        // framing lost: typed reply, then close
        Err(msg) => return ReadEvent::Bad { code: ErrorCode::Malformed, msg, fatal: true },
    };
    if len > max_frame {
        // header is intact, so framing survives: stream the payload to
        // the floor in bounded chunks, then reject — connection keeps
        let mut remaining = len;
        let mut sink = [0u8; 4096];
        while remaining > 0 {
            let want = remaining.min(sink.len());
            if let Err(ev) = read_full(stream, &mut sink[..want], true, stall) {
                return ev;
            }
            remaining -= want;
        }
        return ReadEvent::Bad {
            code: ErrorCode::Oversized,
            msg: format!("payload of {len} bytes exceeds cap {max_frame}"),
            fatal: false,
        };
    }
    let mut payload = vec![0u8; len];
    if let Err(ev) = read_full(stream, &mut payload, true, stall) {
        return ev;
    }
    if crate::util::fnv::fnv64(&payload) != payload_fnv {
        // exactly `len` bytes consumed: framing intact, keep connection
        return ReadEvent::Bad {
            code: ErrorCode::Malformed,
            msg: "payload checksum mismatch".to_string(),
            fatal: false,
        };
    }
    ReadEvent::Frame(Frame { kind, req_id, payload })
}

fn reader_loop(shared: &Arc<Shared>, stream: TcpStream) {
    if stream.set_read_timeout(Some(Duration::from_millis(50))).is_err() {
        return;
    }
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let conn = Arc::new(Conn::new(write_half));
    let stall = Duration::from_millis(shared.cfg.frame_stall_ms.max(1));
    let mut stream = stream;
    loop {
        match read_event(&mut stream, shared.cfg.max_frame_bytes, stall) {
            ReadEvent::Idle => {
                if shared.draining.load(Ordering::SeqCst) {
                    return;
                }
            }
            ReadEvent::Closed | ReadEvent::Dead => return,
            ReadEvent::Bad { code, msg, fatal } => {
                shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let _ = conn.send(FrameKind::Error, 0, &encode_error(code, &msg));
                if fatal {
                    return;
                }
            }
            ReadEvent::Frame(frame) => {
                if !handle_frame(shared, &conn, frame) {
                    return;
                }
            }
        }
    }
}

/// Dispatch one request frame; `false` ends the connection.
fn handle_frame(shared: &Arc<Shared>, conn: &Arc<Conn>, frame: Frame) -> bool {
    let id = frame.req_id;
    match frame.kind {
        FrameKind::Ping => {
            let _ = conn.send(FrameKind::Pong, id, &[]);
            true
        }
        FrameKind::Status => {
            let body = status_json(shared).to_string();
            let _ = conn.send(FrameKind::StatusReply, id, body.as_bytes());
            true
        }
        FrameKind::Drain => {
            shared.draining.store(true, Ordering::SeqCst);
            shared.queue.wake_all();
            let _ = conn.send(FrameKind::DrainOk, id, &[]);
            // stop reading; queued requests from this conn still answer
            // through the shared writer before the daemon exits
            false
        }
        FrameKind::Swap => {
            let path = match super::protocol::decode_swap(&frame.payload) {
                Ok(p) => p,
                Err(msg) => {
                    shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = conn.send(FrameKind::Error, id, &encode_error(ErrorCode::Malformed, &msg));
                    return true;
                }
            };
            match shared.slot.swap_from_path(&path) {
                Ok(version) => {
                    shared.stats.swaps_ok.fetch_add(1, Ordering::Relaxed);
                    let _ = conn.send(FrameKind::SwapOk, id, &version.to_le_bytes());
                }
                Err(e) => {
                    shared.stats.swaps_failed.fetch_add(1, Ordering::Relaxed);
                    let _ = conn
                        .send(FrameKind::Error, id, &encode_error(ErrorCode::BadModel, &e.to_string()));
                }
            }
            true
        }
        FrameKind::Predict => {
            if shared.draining.load(Ordering::SeqCst) {
                shared.stats.drain_rejects.fetch_add(1, Ordering::Relaxed);
                let _ = conn.send(
                    FrameKind::Error,
                    id,
                    &encode_error(ErrorCode::Draining, "daemon is draining"),
                );
                return true;
            }
            let (deadline_ms, x) = match super::protocol::decode_predict(&frame.payload) {
                Ok(v) => v,
                Err(msg) => {
                    shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    let _ = conn.send(FrameKind::Error, id, &encode_error(ErrorCode::Malformed, &msg));
                    return true;
                }
            };
            let d = shared.slot.current().model.input_dim();
            if x.cols != d {
                shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("model expects {d} input features, batch has {}", x.cols);
                let _ = conn.send(FrameKind::Error, id, &encode_error(ErrorCode::Malformed, &msg));
                return true;
            }
            let ms = if deadline_ms == 0 { shared.cfg.default_deadline_ms } else { deadline_ms as u64 };
            let req = PendingRequest {
                conn: conn.clone(),
                req_id: id,
                x,
                deadline: Instant::now() + Duration::from_millis(ms),
            };
            if let Err(req) = shared.queue.try_push(req) {
                shared.stats.shed.fetch_add(1, Ordering::Relaxed);
                let msg = format!("admission queue full (cap {})", shared.cfg.queue_cap);
                let _ =
                    req.conn.send(FrameKind::Error, req.req_id, &encode_error(ErrorCode::Overloaded, &msg));
            }
            true
        }
        // response kinds arriving at the server are a protocol violation
        _ => {
            shared.stats.protocol_errors.fetch_add(1, Ordering::Relaxed);
            let _ = conn.send(
                FrameKind::Error,
                id,
                &encode_error(ErrorCode::Malformed, "response frame sent to server"),
            );
            false
        }
    }
}

/// Build the STATUS document.
fn status_json(shared: &Arc<Shared>) -> Json {
    let cur = shared.slot.current();
    let drift = cur.model.drift_stats();
    let s = &shared.stats;
    let mut o = Json::obj();
    o.set("model_version", Json::Num(cur.version as f64))
        .set("workers", Json::Num(shared.cfg.workers as f64))
        .set("queue_depth", Json::Num(shared.queue.depth() as f64))
        .set("queue_cap", Json::Num(shared.cfg.queue_cap as f64))
        .set("draining", Json::Bool(shared.draining.load(Ordering::SeqCst)))
        .set("connections", Json::Num(s.connections.load(Ordering::Relaxed) as f64))
        .set("served_requests", Json::Num(s.served_requests.load(Ordering::Relaxed) as f64))
        .set("served_points", Json::Num(s.served_points.load(Ordering::Relaxed) as f64))
        .set("batches", Json::Num(s.batches.load(Ordering::Relaxed) as f64))
        .set("shed", Json::Num(s.shed.load(Ordering::Relaxed) as f64))
        .set("timeouts", Json::Num(s.timeouts.load(Ordering::Relaxed) as f64))
        .set("restarts", Json::Num(s.restarts.load(Ordering::Relaxed) as f64))
        .set("protocol_errors", Json::Num(s.protocol_errors.load(Ordering::Relaxed) as f64))
        .set("internal_rejects", Json::Num(s.internal_rejects.load(Ordering::Relaxed) as f64))
        .set("drain_rejects", Json::Num(s.drain_rejects.load(Ordering::Relaxed) as f64))
        .set("swaps_ok", Json::Num(s.swaps_ok.load(Ordering::Relaxed) as f64))
        .set("swaps_failed", Json::Num(s.swaps_failed.load(Ordering::Relaxed) as f64));
    let mut drift_o = Json::obj();
    drift_o
        .set("points", Json::Num(drift.points as f64))
        .set("lookups", Json::Num(drift.lookups as f64))
        .set("unseen", Json::Num(drift.unseen as f64))
        .set("over_threshold", Json::Num(drift.over_threshold as f64))
        .set("warnings", Json::Num(drift.warnings as f64))
        .set("rate", Json::Num(drift.rate()));
    o.set("drift", drift_o);
    // online-maintenance history carried in the model artifact itself
    // (SCRBMODL v3 trailer): admissions, absorbed rows, drift EWMAs.
    let up = cur.model.update_state;
    let mut up_o = Json::obj();
    up_o.set("updates", Json::Num(up.updates as f64))
        .set("rows_absorbed", Json::Num(up.rows_absorbed as f64))
        .set("bins_admitted", Json::Num(up.bins_admitted as f64))
        .set("refits_signaled", Json::Num(up.refits_signaled as f64))
        .set("unseen_ewma", Json::Num(up.unseen_ewma))
        .set("residual_ewma", Json::Num(up.residual_ewma));
    o.set("update", up_o);
    let swaps: Vec<Json> = shared
        .slot
        .history()
        .into_iter()
        .map(|rec| {
            let mut e = Json::obj();
            e.set("version", Json::Num(rec.version as f64))
                .set("path", Json::Str(rec.path))
                .set("ok", Json::Bool(rec.ok))
                .set("detail", Json::Str(rec.detail));
            e
        })
        .collect();
    o.set("swap_history", Json::Arr(swaps));
    o
}

// ---------------------------------------------------------------------
// Worker (micro-batcher)
// ---------------------------------------------------------------------

/// Per-worker reusable scratch: the serving workspace, the label buffer,
/// and the coalesced input matrix. Rebuilt from scratch after a panic
/// (that is the "restart" — the thread itself survives).
struct WorkerState {
    ws: ServeWorkspace,
    labels: Vec<usize>,
    coalesced: Mat,
}

impl WorkerState {
    fn fresh() -> WorkerState {
        WorkerState { ws: ServeWorkspace::new(), labels: Vec::new(), coalesced: Mat::zeros(0, 0) }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let mut state = WorkerState::fresh();
    let mut batch: Vec<PendingRequest> = Vec::new();
    loop {
        let got = shared.queue.pop_batch(shared.cfg.max_batch, &mut batch, || {
            shared.draining.load(Ordering::SeqCst)
                && shared.readers_active.load(Ordering::SeqCst) == 0
        });
        if !got {
            return;
        }
        let vm = shared.slot.current();
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            process_batch(&shared, &vm, &batch, &mut state);
        }));
        if outcome.is_err() {
            // worker restart: rebuild scratch, answer the poisoned
            // batch's requests with a typed Internal rejection
            shared.stats.restarts.fetch_add(1, Ordering::Relaxed);
            state = WorkerState::fresh();
            for r in &batch {
                shared.stats.internal_rejects.fetch_add(1, Ordering::Relaxed);
                let _ = r.conn.send(
                    FrameKind::Error,
                    r.req_id,
                    &encode_error(ErrorCode::Internal, "worker panicked; worker restarted"),
                );
            }
        }
        batch.clear();
    }
}

/// Serve one popped batch against one pinned model version.
fn process_batch(
    shared: &Arc<Shared>,
    vm: &Arc<VersionedModel>,
    batch: &[PendingRequest],
    state: &mut WorkerState,
) {
    let plan = &shared.cfg.fault;
    // injected stalls first (they are what makes deadlines expire in
    // tests), then the deadline gate, then injected panics
    if plan.stall_ms > 0 {
        for r in batch {
            if plan.stalls(r.req_id) {
                std::thread::sleep(Duration::from_millis(plan.stall_ms));
            }
        }
    }
    let now = Instant::now();
    let mut live: Vec<&PendingRequest> = Vec::with_capacity(batch.len());
    for r in batch {
        if now > r.deadline {
            shared.stats.timeouts.fetch_add(1, Ordering::Relaxed);
            let _ = r.conn.send(
                FrameKind::Error,
                r.req_id,
                &encode_error(ErrorCode::Timeout, "deadline expired before a worker was free"),
            );
        } else {
            live.push(r);
        }
    }
    if live.is_empty() {
        return;
    }
    for r in &live {
        if plan.panics(r.req_id) {
            panic!("injected worker panic (req {})", r.req_id);
        }
    }
    shared.stats.batches.fetch_add(1, Ordering::Relaxed);
    if live.len() == 1 {
        // single-request fast path: no copy into the coalesce buffer
        let r = live[0];
        reply_predict(shared, vm, r, &r.x, state);
        return;
    }
    // coalesce rows of every live request into one matrix (capacity
    // reused across batches), one predict_batch, split the label ranges
    let cols = live[0].x.cols;
    let total: usize = live.iter().map(|r| r.x.rows).sum();
    state.coalesced.rows = total;
    state.coalesced.cols = cols;
    state.coalesced.data.clear();
    state.coalesced.data.reserve(total * cols);
    for r in &live {
        state.coalesced.data.extend_from_slice(&r.x.data);
    }
    match vm.model.predict_batch(&state.coalesced, &mut state.ws, &mut state.labels) {
        Ok(()) => {
            let mut off = 0usize;
            for r in &live {
                let n = r.x.rows;
                shared.stats.served_requests.fetch_add(1, Ordering::Relaxed);
                shared.stats.served_points.fetch_add(n as u64, Ordering::Relaxed);
                let _ = r.conn.send(
                    FrameKind::Labels,
                    r.req_id,
                    &encode_labels(vm.version, &state.labels[off..off + n]),
                );
                off += n;
            }
        }
        Err(e) => {
            // admission validated shapes, so this is unexpected: typed
            // Internal rejection for the whole coalesced batch
            for r in &live {
                shared.stats.internal_rejects.fetch_add(1, Ordering::Relaxed);
                let _ = r.conn.send(
                    FrameKind::Error,
                    r.req_id,
                    &encode_error(ErrorCode::Internal, &format!("predict failed: {e}")),
                );
            }
        }
    }
}

/// Predict and answer a single request against the pinned model.
fn reply_predict(
    shared: &Arc<Shared>,
    vm: &Arc<VersionedModel>,
    r: &PendingRequest,
    x: &Mat,
    state: &mut WorkerState,
) {
    match vm.model.predict_batch(x, &mut state.ws, &mut state.labels) {
        Ok(()) => {
            shared.stats.served_requests.fetch_add(1, Ordering::Relaxed);
            shared.stats.served_points.fetch_add(x.rows as u64, Ordering::Relaxed);
            let _ = r.conn.send(
                FrameKind::Labels,
                r.req_id,
                &encode_labels(vm.version, &state.labels),
            );
        }
        Err(e) => {
            shared.stats.internal_rejects.fetch_add(1, Ordering::Relaxed);
            let _ = r.conn.send(
                FrameKind::Error,
                r.req_id,
                &encode_error(ErrorCode::Internal, &format!("predict failed: {e}")),
            );
        }
    }
}
