//! Atomic hot model swap with validate-before-publish and rollback.
//!
//! The served model lives behind an [`ModelSlot`]: workers take an
//! `Arc` snapshot **once per batch**, so a swap can never change the
//! model under an in-flight request — every response is computed, start
//! to finish, against exactly one model version (the version is echoed
//! in the response so clients can verify).
//!
//! A swap publishes only after the candidate passes two gates:
//!
//! 1. the checksummed v2 loader ([`ScRbModel::load`]) — bit-rot,
//!    truncation and bad magic are all typed failures that name the file;
//! 2. a self-check prediction on a probe batch — the model must accept
//!    its own declared input width and emit in-range labels.
//!
//! Any failure leaves the current model untouched (rollback is simply
//! "don't publish") and is recorded in the swap history surfaced by
//! `STATUS`.

use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::model::{FittedModel, ScRbModel, ServeWorkspace};
use std::sync::{Arc, Mutex, RwLock};

/// One served model plus its monotonically increasing version.
pub(crate) struct VersionedModel {
    pub version: u32,
    pub model: ScRbModel,
}

/// One entry of the swap audit trail.
#[derive(Clone, Debug)]
pub struct SwapRecord {
    /// Version published (on success) or the version that *stayed*
    /// published (on a rolled-back failure).
    pub version: u32,
    /// Model file the swap was asked to load.
    pub path: String,
    pub ok: bool,
    /// Human-readable outcome ("published" or the rejection reason).
    pub detail: String,
}

/// The swappable model slot.
pub(crate) struct ModelSlot {
    cur: RwLock<Arc<VersionedModel>>,
    history: Mutex<Vec<SwapRecord>>,
}

impl ModelSlot {
    pub fn new(model: ScRbModel) -> ModelSlot {
        ModelSlot {
            cur: RwLock::new(Arc::new(VersionedModel { version: 1, model })),
            history: Mutex::new(Vec::new()),
        }
    }

    /// Snapshot the current model. Cheap (one `Arc` clone under a read
    /// lock); callers hold the snapshot for the duration of a batch.
    pub fn current(&self) -> Arc<VersionedModel> {
        self.cur.read().unwrap().clone()
    }

    /// The swap audit trail, oldest first.
    pub fn history(&self) -> Vec<SwapRecord> {
        self.history.lock().unwrap().clone()
    }

    /// Validate the model file at `path` and atomically publish it.
    /// On any failure the currently served model stays published and the
    /// error (which names the offending path) is returned.
    pub fn swap_from_path(&self, path: &str) -> Result<u32, ScrbError> {
        match self.validate(path) {
            Ok(candidate) => {
                let mut w = self.cur.write().unwrap();
                let version = w.version + 1;
                *w = Arc::new(VersionedModel { version, model: candidate });
                drop(w);
                self.history.lock().unwrap().push(SwapRecord {
                    version,
                    path: path.to_string(),
                    ok: true,
                    detail: "published".to_string(),
                });
                Ok(version)
            }
            Err(e) => {
                let kept = self.current().version;
                self.history.lock().unwrap().push(SwapRecord {
                    version: kept,
                    path: path.to_string(),
                    ok: false,
                    detail: e.to_string(),
                });
                Err(e)
            }
        }
    }

    /// The two validation gates: checksummed load, then a self-check
    /// prediction on a probe batch.
    fn validate(&self, path: &str) -> Result<ScRbModel, ScrbError> {
        let candidate = ScRbModel::load(path)?;
        let cur = self.current();
        let d = cur.model.input_dim();
        if candidate.input_dim() != d {
            return Err(ScrbError::serve(format!(
                "swap rejected: {path} expects {} input features, serving traffic has {d}",
                candidate.input_dim()
            )));
        }
        if candidate.n_clusters() == 0 {
            return Err(ScrbError::serve(format!("swap rejected: {path} has zero clusters")));
        }
        // self-check: the candidate must label a probe batch without
        // erroring and stay in label range
        let probe = Mat::zeros(2, d);
        let mut ws = ServeWorkspace::new();
        let mut labels = Vec::new();
        candidate.predict_batch(&probe, &mut ws, &mut labels).map_err(|e| {
            ScrbError::serve(format!("swap rejected: {path} failed self-check predict: {e}"))
        })?;
        if labels.iter().any(|&l| l >= candidate.n_clusters()) {
            return Err(ScrbError::serve(format!(
                "swap rejected: {path} emitted out-of-range labels in self-check"
            )));
        }
        Ok(candidate)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("scrb_swap_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    // a tiny real model: fit-quality is irrelevant, only serving shape
    fn toy(seed: u64) -> ScRbModel {
        crate::serve::test_model(40, 4, 3, seed)
    }

    #[test]
    fn swap_publishes_and_bumps_version() {
        let slot = ModelSlot::new(toy(1));
        assert_eq!(slot.current().version, 1);
        let dir = tmpdir("pub");
        let path = dir.join("next.scrb");
        toy(2).save(path.to_str().unwrap()).unwrap();
        let v = slot.swap_from_path(path.to_str().unwrap()).unwrap();
        assert_eq!(v, 2);
        assert_eq!(slot.current().version, 2);
        let h = slot.history();
        assert_eq!(h.len(), 1);
        assert!(h[0].ok);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupt_swap_rolls_back_and_names_path() {
        let slot = ModelSlot::new(toy(3));
        let before = slot.current();
        let dir = tmpdir("corrupt");
        let path = dir.join("bad.scrb");
        let mut bytes = toy(4).to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let err = slot.swap_from_path(path.to_str().unwrap()).unwrap_err();
        assert!(err.to_string().contains("bad.scrb"), "{err}");
        // rollback: same Arc still published, version unchanged
        let after = slot.current();
        assert_eq!(after.version, before.version);
        assert!(Arc::ptr_eq(&before, &after));
        let h = slot.history();
        assert_eq!(h.len(), 1);
        assert!(!h[0].ok);
        assert_eq!(h[0].version, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dim_mismatch_is_rejected() {
        let slot = ModelSlot::new(toy(5));
        let dir = tmpdir("dim");
        let path = dir.join("wide.scrb");
        // d_in = 5 instead of the toy default 3
        crate::serve::test_model_dim(40, 4, 3, 5, 6).save(path.to_str().unwrap()).unwrap();
        let err = slot.swap_from_path(path.to_str().unwrap()).unwrap_err();
        assert!(err.to_string().contains("input features"), "{err}");
        assert_eq!(slot.current().version, 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
