//! Length-prefixed binary wire protocol of the serving daemon.
//!
//! Every message is one **frame**: a fixed 33-byte header followed by a
//! payload. The header carries its own FNV-1a checksum *and* the
//! payload's, so the reader can tell three failure classes apart and
//! answer each differently (see [`crate::serve`]):
//!
//! - a broken header (bad magic, bad header checksum, truncation inside
//!   the header) destroys framing — the daemon answers a typed
//!   [`ErrorCode::Malformed`] and closes, because it can no longer find
//!   the next frame boundary;
//! - an intact header with an oversized declared length is answered with
//!   [`ErrorCode::Oversized`] and the payload is *discarded in a bounded
//!   stream*, keeping the connection usable;
//! - an intact header whose payload fails its checksum (or fails to
//!   decode) is answered with [`ErrorCode::Malformed`] but the connection
//!   survives — exactly `len` bytes were consumed, so framing is intact.
//!
//! Wire layout (all little-endian):
//!
//! ```text
//!   offset  size  field
//!        0     4  magic  "SCRB"
//!        4     1  kind          (FrameKind)
//!        5     8  req_id        (echoed verbatim in the response)
//!       13     4  len           (payload byte count)
//!       17     8  payload_fnv   (FNV-1a of the payload bytes)
//!       25     8  header_fnv    (FNV-1a of header bytes [0, 25))
//!       33   len  payload
//! ```

use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::util::fnv::fnv64;
use std::io::Read;

/// `"SCRB"` as little-endian bytes on the wire.
pub const MAGIC: u32 = u32::from_le_bytes(*b"SCRB");

/// Fixed frame-header size in bytes.
pub const HEADER_LEN: usize = 33;

/// Default per-frame payload cap (64 MiB ≈ a one-million-point f64 batch
/// at d=8); configurable per server.
pub const DEFAULT_MAX_FRAME: usize = 64 << 20;

/// What a frame is: requests flow client→server (low codes), responses
/// server→client (high bit set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// Label a batch of points (payload: deadline_ms, rows, cols, data).
    Predict,
    /// Ask for the daemon's status JSON.
    Status,
    /// Hot-swap the served model to the file named in the payload.
    Swap,
    /// Begin a graceful drain (stop admitting, finish in-flight, exit).
    Drain,
    /// Liveness probe.
    Ping,
    /// Labels response (payload: model version, n, labels).
    Labels,
    /// Status response (payload: JSON text).
    StatusReply,
    /// Swap succeeded (payload: new model version).
    SwapOk,
    /// Typed rejection (payload: [`ErrorCode`] + message).
    Error,
    /// Ping response.
    Pong,
    /// Drain acknowledged.
    DrainOk,
}

impl FrameKind {
    pub fn as_u8(self) -> u8 {
        match self {
            FrameKind::Predict => 1,
            FrameKind::Status => 2,
            FrameKind::Swap => 3,
            FrameKind::Drain => 4,
            FrameKind::Ping => 5,
            FrameKind::Labels => 0x81,
            FrameKind::StatusReply => 0x82,
            FrameKind::SwapOk => 0x83,
            FrameKind::Error => 0x84,
            FrameKind::Pong => 0x85,
            FrameKind::DrainOk => 0x86,
        }
    }

    pub fn from_u8(b: u8) -> Option<FrameKind> {
        Some(match b {
            1 => FrameKind::Predict,
            2 => FrameKind::Status,
            3 => FrameKind::Swap,
            4 => FrameKind::Drain,
            5 => FrameKind::Ping,
            0x81 => FrameKind::Labels,
            0x82 => FrameKind::StatusReply,
            0x83 => FrameKind::SwapOk,
            0x84 => FrameKind::Error,
            0x85 => FrameKind::Pong,
            0x86 => FrameKind::DrainOk,
            _ => return None,
        })
    }

    /// Is this a client→server request kind?
    pub fn is_request(self) -> bool {
        self.as_u8() < 0x80
    }
}

/// Why the daemon rejected a request — the wire-level face of
/// [`ScrbError::Serve`]. Every rejection carries one of these codes plus
/// a human-readable message, so a client can branch on the code (retry
/// on `Overloaded`, never on `Malformed`) without parsing text.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Broken framing or an undecodable/invalid payload.
    Malformed,
    /// Declared payload length exceeds the server's frame cap.
    Oversized,
    /// Admission queue full — request shed by load control.
    Overloaded,
    /// The request's deadline expired before a worker reached it.
    Timeout,
    /// A model swap was rejected (load/validation failed); old model kept.
    BadModel,
    /// The daemon is draining and admits no new work.
    Draining,
    /// A worker failed internally (e.g. panicked) while holding the
    /// request; the worker was restarted.
    Internal,
}

impl ErrorCode {
    pub fn as_u8(self) -> u8 {
        match self {
            ErrorCode::Malformed => 1,
            ErrorCode::Oversized => 2,
            ErrorCode::Overloaded => 3,
            ErrorCode::Timeout => 4,
            ErrorCode::BadModel => 5,
            ErrorCode::Draining => 6,
            ErrorCode::Internal => 7,
        }
    }

    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        Some(match b {
            1 => ErrorCode::Malformed,
            2 => ErrorCode::Oversized,
            3 => ErrorCode::Overloaded,
            4 => ErrorCode::Timeout,
            5 => ErrorCode::BadModel,
            6 => ErrorCode::Draining,
            7 => ErrorCode::Internal,
            _ => return None,
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            ErrorCode::Malformed => "malformed",
            ErrorCode::Oversized => "oversized",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::Timeout => "timeout",
            ErrorCode::BadModel => "bad-model",
            ErrorCode::Draining => "draining",
            ErrorCode::Internal => "internal",
        }
    }
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub req_id: u64,
    pub payload: Vec<u8>,
}

/// Encode a frame: header (with both checksums) + payload.
pub fn encode_frame(kind: FrameKind, req_id: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(kind.as_u8());
    out.extend_from_slice(&req_id.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv64(payload).to_le_bytes());
    let hsum = fnv64(&out[..25]);
    out.extend_from_slice(&hsum.to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// A validated frame header (framing survives; payload not yet read).
pub(crate) struct Header {
    pub kind: FrameKind,
    pub req_id: u64,
    pub len: usize,
    pub payload_fnv: u64,
}

/// Validate 33 header bytes. `Err` messages feed
/// [`ErrorCode::Malformed`] replies; a failure here is **fatal** to the
/// connection (framing is lost).
pub(crate) fn parse_header(h: &[u8; HEADER_LEN]) -> Result<Header, String> {
    let stored = u64::from_le_bytes(h[25..33].try_into().unwrap());
    if fnv64(&h[..25]) != stored {
        return Err("frame header checksum mismatch".to_string());
    }
    let magic = u32::from_le_bytes(h[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(format!("bad magic 0x{magic:08x}"));
    }
    let kind = FrameKind::from_u8(h[4]).ok_or_else(|| format!("unknown frame kind {}", h[4]))?;
    let req_id = u64::from_le_bytes(h[5..13].try_into().unwrap());
    let len = u32::from_le_bytes(h[13..17].try_into().unwrap()) as usize;
    let payload_fnv = u64::from_le_bytes(h[17..25].try_into().unwrap());
    Ok(Header { kind, req_id, len, payload_fnv })
}

/// Blocking frame read for clients (no timeout games): returns a typed
/// [`ScrbError::Serve`] on EOF or corruption.
pub fn read_frame_blocking(r: &mut impl Read, max_frame: usize) -> Result<Frame, ScrbError> {
    let mut h = [0u8; HEADER_LEN];
    r.read_exact(&mut h)
        .map_err(|e| ScrbError::serve(format!("connection lost reading frame header: {e}")))?;
    let header = parse_header(&h).map_err(ScrbError::serve)?;
    if header.len > max_frame {
        return Err(ScrbError::serve(format!(
            "frame payload of {} bytes exceeds cap {max_frame}",
            header.len
        )));
    }
    let mut payload = vec![0u8; header.len];
    r.read_exact(&mut payload)
        .map_err(|e| ScrbError::serve(format!("connection lost reading frame payload: {e}")))?;
    if fnv64(&payload) != header.payload_fnv {
        return Err(ScrbError::serve("frame payload checksum mismatch"));
    }
    Ok(Frame { kind: header.kind, req_id: header.req_id, payload })
}

// ---------------------------------------------------------------------
// Payload codecs. Decoders return `Err(message)` — the message becomes a
// `Malformed` reply; the connection survives (framing was intact).
// ---------------------------------------------------------------------

fn take<'a>(b: &mut &'a [u8], n: usize, what: &str) -> Result<&'a [u8], String> {
    if b.len() < n {
        return Err(format!("truncated payload: wanted {n} bytes for {what}, have {}", b.len()));
    }
    let (head, tail) = b.split_at(n);
    *b = tail;
    Ok(head)
}

fn take_u32(b: &mut &[u8], what: &str) -> Result<u32, String> {
    Ok(u32::from_le_bytes(take(b, 4, what)?.try_into().unwrap()))
}

/// Encode a predict request: deadline (ms, 0 = server default) plus a
/// row-major f64 batch.
pub fn encode_predict(deadline_ms: u32, x: &Mat) -> Vec<u8> {
    let mut p = Vec::with_capacity(12 + x.data.len() * 8);
    p.extend_from_slice(&deadline_ms.to_le_bytes());
    p.extend_from_slice(&(x.rows as u32).to_le_bytes());
    p.extend_from_slice(&(x.cols as u32).to_le_bytes());
    for &v in &x.data {
        p.extend_from_slice(&v.to_le_bytes());
    }
    p
}

/// Decode a predict request into `(deadline_ms, batch)`.
pub fn decode_predict(payload: &[u8]) -> Result<(u32, Mat), String> {
    let mut b = payload;
    let deadline_ms = take_u32(&mut b, "deadline")?;
    let rows = take_u32(&mut b, "rows")? as usize;
    let cols = take_u32(&mut b, "cols")? as usize;
    if rows == 0 || cols == 0 {
        return Err(format!("empty batch ({rows}x{cols})"));
    }
    let want = rows
        .checked_mul(cols)
        .and_then(|n| n.checked_mul(8))
        .ok_or_else(|| format!("batch shape {rows}x{cols} overflows"))?;
    if b.len() != want {
        return Err(format!("batch {rows}x{cols} wants {want} data bytes, payload has {}", b.len()));
    }
    let data: Vec<f64> =
        b.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect();
    Ok((deadline_ms, Mat::from_vec(rows, cols, data)))
}

/// Encode a labels response: the serving model's version plus one u32
/// label per input row.
pub fn encode_labels(version: u32, labels: &[usize]) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + labels.len() * 4);
    p.extend_from_slice(&version.to_le_bytes());
    p.extend_from_slice(&(labels.len() as u32).to_le_bytes());
    for &l in labels {
        p.extend_from_slice(&(l as u32).to_le_bytes());
    }
    p
}

/// Decode a labels response into `(model_version, labels)`.
pub fn decode_labels(payload: &[u8]) -> Result<(u32, Vec<usize>), String> {
    let mut b = payload;
    let version = take_u32(&mut b, "model version")?;
    let n = take_u32(&mut b, "label count")? as usize;
    if b.len() != n * 4 {
        return Err(format!("{n} labels want {} bytes, have {}", n * 4, b.len()));
    }
    let labels =
        b.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap()) as usize).collect();
    Ok((version, labels))
}

/// Encode a typed error response.
pub fn encode_error(code: ErrorCode, msg: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(5 + msg.len());
    p.push(code.as_u8());
    p.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    p.extend_from_slice(msg.as_bytes());
    p
}

/// Decode a typed error response into `(code, message)`.
pub fn decode_error(payload: &[u8]) -> Result<(ErrorCode, String), String> {
    let mut b = payload;
    let raw = take(&mut b, 1, "error code")?[0];
    let code = ErrorCode::from_u8(raw).ok_or_else(|| format!("unknown error code {raw}"))?;
    let n = take_u32(&mut b, "message length")? as usize;
    let msg = take(&mut b, n, "message")?;
    String::from_utf8(msg.to_vec()).map(|m| (code, m)).map_err(|_| "non-UTF-8 message".to_string())
}

/// Encode a swap request: the model file path.
pub fn encode_swap(path: &str) -> Vec<u8> {
    let mut p = Vec::with_capacity(4 + path.len());
    p.extend_from_slice(&(path.len() as u32).to_le_bytes());
    p.extend_from_slice(path.as_bytes());
    p
}

/// Decode a swap request into the model file path.
pub fn decode_swap(payload: &[u8]) -> Result<String, String> {
    let mut b = payload;
    let n = take_u32(&mut b, "path length")? as usize;
    let raw = take(&mut b, n, "path")?;
    String::from_utf8(raw.to_vec()).map_err(|_| "non-UTF-8 path".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let payload = encode_predict(250, &Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        let bytes = encode_frame(FrameKind::Predict, 77, &payload);
        assert_eq!(bytes.len(), HEADER_LEN + payload.len());
        let frame = read_frame_blocking(&mut &bytes[..], DEFAULT_MAX_FRAME).unwrap();
        assert_eq!(frame.kind, FrameKind::Predict);
        assert_eq!(frame.req_id, 77);
        let (dl, x) = decode_predict(&frame.payload).unwrap();
        assert_eq!(dl, 250);
        assert_eq!((x.rows, x.cols), (2, 3));
        assert_eq!(x.data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn every_kind_and_code_roundtrips() {
        for k in [
            FrameKind::Predict,
            FrameKind::Status,
            FrameKind::Swap,
            FrameKind::Drain,
            FrameKind::Ping,
            FrameKind::Labels,
            FrameKind::StatusReply,
            FrameKind::SwapOk,
            FrameKind::Error,
            FrameKind::Pong,
            FrameKind::DrainOk,
        ] {
            assert_eq!(FrameKind::from_u8(k.as_u8()), Some(k));
            assert_eq!(k.is_request(), k.as_u8() < 0x80);
        }
        assert_eq!(FrameKind::from_u8(0), None);
        for c in [
            ErrorCode::Malformed,
            ErrorCode::Oversized,
            ErrorCode::Overloaded,
            ErrorCode::Timeout,
            ErrorCode::BadModel,
            ErrorCode::Draining,
            ErrorCode::Internal,
        ] {
            assert_eq!(ErrorCode::from_u8(c.as_u8()), Some(c));
            assert!(!c.as_str().is_empty());
        }
        assert_eq!(ErrorCode::from_u8(0), None);
    }

    #[test]
    fn header_corruption_is_detected() {
        let bytes = encode_frame(FrameKind::Ping, 1, b"");
        // flip any header byte: parse_header must reject
        for pos in 0..HEADER_LEN {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x40;
            let h: [u8; HEADER_LEN] = bad[..HEADER_LEN].try_into().unwrap();
            assert!(parse_header(&h).is_err(), "flip at {pos} undetected");
        }
        let good: [u8; HEADER_LEN] = bytes[..HEADER_LEN].try_into().unwrap();
        assert!(parse_header(&good).is_ok());
    }

    #[test]
    fn payload_corruption_is_detected() {
        let bytes = encode_frame(FrameKind::Swap, 9, &encode_swap("/tmp/m.scrb"));
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0x01;
        let err = read_frame_blocking(&mut &bad[..], DEFAULT_MAX_FRAME).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn truncation_is_a_typed_error() {
        let bytes = encode_frame(FrameKind::Ping, 3, b"xyz");
        for cut in 0..bytes.len() {
            assert!(
                read_frame_blocking(&mut &bytes[..cut], DEFAULT_MAX_FRAME).is_err(),
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn predict_decoder_rejects_bad_shapes() {
        // empty batch
        let p = encode_predict(0, &Mat::zeros(1, 1));
        let mut b = p.clone();
        b[4..8].copy_from_slice(&0u32.to_le_bytes());
        assert!(decode_predict(&b).is_err());
        // length mismatch (lying row count)
        let mut b = p.clone();
        b[4..8].copy_from_slice(&5u32.to_le_bytes());
        assert!(decode_predict(&b).is_err());
        // truncated data
        assert!(decode_predict(&p[..p.len() - 1]).is_err());
    }

    #[test]
    fn labels_and_error_codecs_roundtrip() {
        let p = encode_labels(3, &[0, 2, 1, 2]);
        assert_eq!(decode_labels(&p).unwrap(), (3, vec![0, 2, 1, 2]));
        assert!(decode_labels(&p[..p.len() - 2]).is_err());
        let e = encode_error(ErrorCode::Overloaded, "queue full (cap 256)");
        let (code, msg) = decode_error(&e).unwrap();
        assert_eq!(code, ErrorCode::Overloaded);
        assert_eq!(msg, "queue full (cap 256)");
        assert_eq!(decode_swap(&encode_swap("/a/b.scrb")).unwrap(), "/a/b.scrb");
    }
}
