//! Bounded admission queue and the shared per-connection writer.
//!
//! Admission control is explicit: [`AdmitQueue::try_push`] either accepts
//! a request or hands it straight back — the caller (the connection
//! reader) answers the client with a typed
//! [`ErrorCode::Overloaded`](super::protocol::ErrorCode::Overloaded)
//! rejection. Nothing blocks on a full queue and nothing is silently
//! dropped: under overload the daemon *sheds* load and says so.
//!
//! Workers take work through [`AdmitQueue::pop_batch`], which coalesces
//! up to `max_batch` queued requests in one grab — the micro-batching
//! window. The wait is a condvar with a short timeout so workers also
//! observe drain without a dedicated wake-up.

use super::protocol::{encode_frame, FrameKind};
use crate::linalg::Mat;
use std::collections::VecDeque;
use std::io::Write;
use std::net::TcpStream;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Shared write half of one client connection. Readers (typed rejects,
/// control replies) and workers (prediction results) both respond
/// through it; the mutex keeps concurrently-written frames from
/// interleaving on the wire.
pub(crate) struct Conn {
    stream: Mutex<TcpStream>,
}

impl Conn {
    pub fn new(stream: TcpStream) -> Conn {
        Conn { stream: Mutex::new(stream) }
    }

    /// Write one response frame. A failure means the client is gone —
    /// the daemon's obligation ends there, so the error is returned only
    /// for accounting, never escalated.
    pub fn send(&self, kind: FrameKind, req_id: u64, payload: &[u8]) -> std::io::Result<()> {
        let bytes = encode_frame(kind, req_id, payload);
        // a poisoned lock (panicked sender) must not cascade: the stream
        // holds no partial frame unless the panic hit write_all itself,
        // and the peer's checksums catch that case
        let mut s = self.stream.lock().unwrap_or_else(|p| p.into_inner());
        s.write_all(&bytes)
    }
}

/// One admitted prediction request, waiting for a worker.
pub(crate) struct PendingRequest {
    pub conn: std::sync::Arc<Conn>,
    pub req_id: u64,
    pub x: Mat,
    /// Absolute deadline; a worker reaching the request after this
    /// answers `Timeout` instead of predicting.
    pub deadline: Instant,
}

/// Bounded FIFO of admitted requests.
pub(crate) struct AdmitQueue {
    inner: Mutex<VecDeque<PendingRequest>>,
    notify: Condvar,
    cap: usize,
}

impl AdmitQueue {
    pub fn new(cap: usize) -> AdmitQueue {
        AdmitQueue { inner: Mutex::new(VecDeque::new()), notify: Condvar::new(), cap: cap.max(1) }
    }

    /// Admit `req`, or hand it back if the queue is at capacity (the
    /// caller sheds it with a typed rejection).
    pub fn try_push(&self, req: PendingRequest) -> Result<(), PendingRequest> {
        let mut q = self.inner.lock().unwrap();
        if q.len() >= self.cap {
            return Err(req);
        }
        q.push_back(req);
        drop(q);
        self.notify.notify_one();
        Ok(())
    }

    /// Move up to `max_batch` requests into `out` (cleared first).
    /// Blocks in short condvar waits while empty; returns `false` once
    /// `stopped()` holds *and* the queue is empty — the worker's signal
    /// that the drain is complete and it should exit.
    pub fn pop_batch(
        &self,
        max_batch: usize,
        out: &mut Vec<PendingRequest>,
        stopped: impl Fn() -> bool,
    ) -> bool {
        out.clear();
        let mut q = self.inner.lock().unwrap();
        while q.is_empty() {
            if stopped() {
                return false;
            }
            let (guard, _timeout) =
                self.notify.wait_timeout(q, Duration::from_millis(20)).unwrap();
            q = guard;
        }
        let take = q.len().min(max_batch.max(1));
        out.extend(q.drain(..take));
        true
    }

    /// Wake every waiting worker (used when drain begins).
    pub fn wake_all(&self) {
        self.notify.notify_all();
    }

    /// Current queue depth (for STATUS).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().len()
    }
}
