//! Little-endian byte (de)serialization for the versioned model format.
//!
//! The offline vendor set has no serde, so the model file is a hand-rolled
//! layout: fixed-width little-endian scalars and length-prefixed arrays,
//! written through [`ByteWriter`] and read back through the bounds-checked
//! [`ByteReader`] (truncation or garbage becomes a clean
//! [`ScrbError::Model`], never a panic or an out-of-bounds read).
//!
//! Checksummed images: [`ByteWriter::finish_with_checksum`] appends an
//! FNV-1a 64-bit digest of everything written, and [`split_checksummed`]
//! verifies-and-strips it on load — so bit-rot or truncation *anywhere*
//! in a v2 model file is detected up front, not discovered as a garbage
//! field mid-parse (or worse, not at all).

use crate::error::ScrbError;
// The one FNV-1a definition of the crate (util::fnv): footer checksums
// here must stay bit-compatible with the checkpoint footers and pipeline
// fingerprints that share it.
pub(crate) use crate::util::fnv::fnv64;

/// Verify and strip the 8-byte checksum footer of an image produced by
/// [`ByteWriter::finish_with_checksum`]. `None` means the image is
/// corrupt or truncated (including too short to even hold a footer).
pub(crate) fn split_checksummed(bytes: &[u8]) -> Option<&[u8]> {
    if bytes.len() < 8 {
        return None;
    }
    let (payload, footer) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(footer.try_into().unwrap());
    (fnv64(payload) == stored).then_some(payload)
}

/// Append-only little-endian buffer writer.
pub(crate) struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter { buf: Vec::new() }
    }

    pub fn bytes(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64_slice(&mut self, vs: &[f64]) {
        self.buf.reserve(vs.len() * 8);
        for &v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    /// Finish the image with an FNV-1a checksum footer over everything
    /// written (verified by [`split_checksummed`] on load).
    pub fn finish_with_checksum(mut self) -> Vec<u8> {
        let sum = fnv64(&self.buf);
        self.buf.extend_from_slice(&sum.to_le_bytes());
        self.buf
    }
}

/// Bounds-checked little-endian reader over a model payload.
pub(crate) struct ByteReader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(b: &'a [u8]) -> ByteReader<'a> {
        ByteReader { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ScrbError> {
        if self.i + n > self.b.len() {
            return Err(ScrbError::model(format!(
                "truncated model file: wanted {n} bytes at offset {}, have {}",
                self.i,
                self.b.len() - self.i
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8], ScrbError> {
        self.take(n)
    }

    pub fn u8(&mut self) -> Result<u8, ScrbError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, ScrbError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, ScrbError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, ScrbError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read `n` f64 values. `n` has already been validated against a
    /// sanity cap by the caller, but the read itself is still
    /// bounds-checked, so a lying length prefix fails cleanly.
    pub fn f64_vec(&mut self, n: usize) -> Result<Vec<f64>, ScrbError> {
        let raw = self.take(n.checked_mul(8).ok_or_else(|| {
            ScrbError::model(format!("array length {n} overflows"))
        })?)?;
        Ok(raw.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars_and_slices() {
        let mut w = ByteWriter::new();
        w.bytes(b"MAGIC");
        w.u8(7);
        w.u32(123_456);
        w.u64(0xdead_beef_cafe_f00d);
        w.f64(-1.5e300);
        w.f64_slice(&[0.0, 1.0, -2.25]);
        let buf = w.finish();

        let mut r = ByteReader::new(&buf);
        assert_eq!(r.bytes(5).unwrap(), b"MAGIC");
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 123_456);
        assert_eq!(r.u64().unwrap(), 0xdead_beef_cafe_f00d);
        assert_eq!(r.f64().unwrap(), -1.5e300);
        assert_eq!(r.f64_vec(3).unwrap(), vec![0.0, 1.0, -2.25]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn truncation_is_a_clean_error() {
        let mut w = ByteWriter::new();
        w.u64(1);
        let buf = w.finish();
        let mut r = ByteReader::new(&buf[..5]);
        assert!(r.u64().is_err());
        let mut r2 = ByteReader::new(&buf);
        assert!(r2.f64_vec(100).is_err());
    }

    #[test]
    fn checksum_footer_roundtrips_and_detects_damage() {
        let mut w = ByteWriter::new();
        w.bytes(b"payload");
        w.u64(42);
        let buf = w.finish_with_checksum();
        assert_eq!(buf.len(), 7 + 8 + 8);
        assert_eq!(split_checksummed(&buf).unwrap(), &buf[..15]);
        // any single-bit flip (payload or footer) is caught
        for pos in 0..buf.len() {
            let mut bad = buf.clone();
            bad[pos] ^= 0x01;
            assert!(split_checksummed(&bad).is_none(), "flip at {pos} undetected");
        }
        // any truncation is caught
        for cut in 0..buf.len() {
            assert!(split_checksummed(&buf[..cut]).is_none(), "truncation to {cut} undetected");
        }
        // empty payload is still valid when checksummed
        let empty = ByteWriter::new().finish_with_checksum();
        assert_eq!(split_checksummed(&empty).unwrap(), b"");
    }
}
