//! Fit / transform / predict model layer — the serving face of the crate.
//!
//! The paper's pipeline (Algorithm 2) is a one-shot batch computation;
//! production serving needs the opposite shape: **fit once, assign new
//! points many times**. Random Binning makes that natural — the feature
//! map is data-independent (grids are drawn from the kernel, Algorithm 1),
//! so a new point's R-sparse feature vector projects into the learned
//! spectral embedding the same way Nyström-style out-of-sample extension
//! works for landmark methods:
//!
//! ```text
//!   fit:      Ẑ = D^{-1/2} Z,  Ẑ ≈ U Σ Vᵀ,  centroids = kmeans(rows of U)
//!   predict:  e(x) = z(x) · V · Σ⁻¹      (R·K flops — microseconds)
//!             label = argmin_c ‖ e(x)/‖e(x)‖ − centroid_c ‖²
//! ```
//!
//! The degree normalization cancels under row normalization (it is a
//! per-row scalar), so training points predict to exactly their fit
//! labels, and held-out points land in the cluster whose spectral
//! neighbourhood they bin into.
//!
//! Three pieces:
//! - [`ClusterModel`] — anything that can `fit(&Env, &Mat)` into a
//!   [`FitResult`]: the training-set [`ClusterOutput`] (labels, timings,
//!   solver telemetry — exactly what the old batch `run` returned) plus a
//!   boxed [`FittedModel`]. Every [`crate::cluster::MethodKind`]
//!   implements it; the batch `run` API is now a thin wrapper.
//! - [`FittedModel`] — the serving trait: `transform` (embedding rows),
//!   `predict` (allocating convenience) and `predict_batch` (the hot
//!   path: workspace-reusing, thread-parallel, and allocation-free in
//!   steady state beyond the output vector — enforced by
//!   `tests/alloc.rs`). [`FittedModel::save`] persists models that
//!   support it ([`ScRbModel`]'s versioned binary format).
//! - [`ScRbModel`] — the paper method's fitted artifact: RB codebook
//!   (grid widths/biases, seed, bin→column tables), singular triplets
//!   (Σ, V folded into a projection), and K-means centroids.
//!
//! Baselines without a native out-of-sample extension (exact SC, LSC,
//! Nyström, the RF family, sampled kernel K-means) serve through
//! [`CentroidModel`] — nearest class-mean in input space. For plain
//! K-means that is *exact* (the centroids are the model); for the
//! transductive spectral baselines it is a documented approximation.

pub mod persist;
pub mod scrb;

pub use self::scrb::{
    DriftMonitor, DriftStats, ScRbModel, UpdateState, DEFAULT_UNSEEN_WARN, UPDATE_TRAILER_BYTES,
    WARN_EVERY,
};

use crate::cluster::{ClusterOutput, Env};
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::util::threads::num_threads;

/// Anything that can be fitted to a training matrix under an [`Env`].
pub trait ClusterModel {
    /// Fit on `x` (N×d), producing the training-set clustering output and
    /// a serving model.
    fn fit(&self, env: &Env, x: &Mat) -> Result<FitResult, ScrbError>;
}

/// What a fit produces: the batch output on the training set (labels in
/// row order, per-stage timings, solver telemetry) and the fitted model.
pub struct FitResult {
    /// Serving artifact — keep it to assign new points.
    pub model: Box<dyn FittedModel>,
    /// Training-set clustering, identical to what the old batch `run`
    /// returned.
    pub output: ClusterOutput,
}

/// A fitted model: embeds and labels points that were never seen at fit
/// time.
pub trait FittedModel: Send + Sync {
    /// Number of clusters K.
    fn n_clusters(&self) -> usize;

    /// Input dimensionality d expected by `transform`/`predict`.
    fn input_dim(&self) -> usize;

    /// Serving embedding of each row of `x` (the space `predict` measures
    /// centroid distances in). For [`ScRbModel`] these are row-normalized
    /// spectral embedding rows `z·V·Σ⁻¹`; for [`CentroidModel`] the
    /// serving space is the input space itself (identity).
    fn transform(&self, x: &Mat) -> Result<Mat, ScrbError>;

    /// Cluster labels for the rows of `x` (allocating convenience
    /// wrapper; serving loops should hold a [`ServeWorkspace`] and call
    /// [`FittedModel::predict_batch`]).
    fn predict(&self, x: &Mat) -> Result<Vec<usize>, ScrbError> {
        let mut ws = ServeWorkspace::new();
        let mut out = Vec::new();
        self.predict_batch(x, &mut ws, &mut out)?;
        Ok(out)
    }

    /// Serving hot path: labels for a batch of points, written into
    /// `out` (resized to N), parallel over row strips, reusing `ws`
    /// across calls. Steady state (same batch shape, warm workspace)
    /// performs zero heap allocations beyond the output vector.
    fn predict_batch(
        &self,
        x: &Mat,
        ws: &mut ServeWorkspace,
        out: &mut Vec<usize>,
    ) -> Result<(), ScrbError>;

    /// Attach the input-preprocessing frame (per-feature min and span)
    /// that the caller normalized the *training* data with. Models that
    /// support persistence carry it, so a serving batch can be brought
    /// into the fitted frame — normalizing new data by its **own** batch
    /// statistics would shift every bin coordinate and silently corrupt
    /// predictions. Default: no-op (model serves in the caller's raw
    /// feature frame).
    fn set_input_norm(&mut self, min: Vec<f64>, span: Vec<f64>) {
        let _ = (min, span);
    }

    /// The stored input normalization, if any: `(min, span)` per feature.
    fn input_norm(&self) -> Option<(&[f64], &[f64])> {
        None
    }

    /// Bring a raw batch into the fitted frame (no-op when no
    /// normalization is stored): `x[i][j] ← (x[i][j] − min[j]) / span[j]`.
    fn apply_input_norm(&self, x: &mut Mat) {
        if let Some((min, span)) = self.input_norm() {
            for i in 0..x.rows {
                // zip: a dimension mismatch surfaces as a typed error at
                // the subsequent predict/transform, not a panic here
                for (v, (&m, &s)) in x.row_mut(i).iter_mut().zip(min.iter().zip(span.iter())) {
                    *v = (*v - m) / s;
                }
            }
        }
    }

    /// Persist the model to `path`. Default: not supported by this model
    /// kind ([`ScRbModel`] overrides with its versioned binary format).
    fn save(&self, path: &str) -> Result<(), ScrbError> {
        let _ = path;
        Err(ScrbError::unsupported(
            "this model kind has no persistence format (only SC_RB models can be saved)",
        ))
    }

    /// Recover the concrete model type from a boxed trait object
    /// (`Box::downcast` via `Any`). The streaming driver extracts its
    /// owned [`ScRbModel`] this way after the shared pipeline assembly,
    /// so the model is built exactly once.
    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any>;
}

/// Reusable serving scratch: per-worker row-strip boundaries plus one
/// embedding buffer per worker. Provisioned lazily on first use and
/// re-provisioned only when the batch size, embedding width, or thread
/// count outgrows what is held — steady-state `predict_batch` calls
/// perform no heap allocation.
pub struct ServeWorkspace {
    /// Ascending row boundaries spanning `[0, n]`, one strip per worker.
    bounds: Vec<usize>,
    /// Flat per-worker embedding scratch, `nt × k_cap`.
    scratch: Vec<f64>,
    /// Worker count the strips were built for.
    nt: usize,
    /// Embedding width the scratch was provisioned for.
    k_cap: usize,
    /// Batch size the strips were built for.
    n_rows: usize,
}

impl Default for ServeWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeWorkspace {
    pub fn new() -> ServeWorkspace {
        ServeWorkspace { bounds: Vec::new(), scratch: Vec::new(), nt: 0, k_cap: 0, n_rows: 0 }
    }

    /// (Re)provision for an `n`-row batch with `k`-wide embedding
    /// scratch. No-op (and allocation-free) when nothing changed; a
    /// smaller batch reuses the existing capacity.
    pub(crate) fn prepare(&mut self, n: usize, k: usize) {
        let nt = num_threads().clamp(1, n.max(1));
        if nt != self.nt || n != self.n_rows {
            self.bounds.clear();
            self.bounds.reserve(nt + 1);
            for t in 0..=nt {
                self.bounds.push(t * n / nt);
            }
            self.nt = nt;
            self.n_rows = n;
        }
        if k > self.k_cap || self.scratch.len() < self.nt * self.k_cap.max(k) {
            self.k_cap = self.k_cap.max(k);
            self.scratch.resize(self.nt * self.k_cap, 0.0);
        }
    }

    pub(crate) fn bounds(&self) -> &[usize] {
        &self.bounds
    }

    /// Per-worker scratch stride in f64 elements.
    pub(crate) fn stride(&self) -> usize {
        self.k_cap
    }

    pub(crate) fn scratch_ptr(&mut self) -> *mut f64 {
        self.scratch.as_mut_ptr()
    }
}

/// Index of the centroid row nearest to `e` — a thin delegate to the one
/// argmin in [`crate::kmeans::nearest_centroid`], so serve-time
/// prediction and fit-time assignment share the same scan (same
/// arithmetic, same lowest-index tie-break).
pub(crate) fn nearest_centroid(centroids: &Mat, e: &[f64]) -> usize {
    crate::kmeans::nearest_centroid(e, centroids).0 as usize
}

/// Per-cluster means of `x` rows under `labels` (K×d). Clusters with no
/// members keep a zero row.
pub fn class_means(x: &Mat, labels: &[usize], k: usize) -> Mat {
    assert_eq!(labels.len(), x.rows, "one label per row");
    let mut m = Mat::zeros(k, x.cols);
    let mut counts = vec![0usize; k];
    for (i, &l) in labels.iter().enumerate() {
        assert!(l < k, "label {l} out of range for k={k}");
        counts[l] += 1;
        let row = x.row(i);
        let mrow = m.row_mut(l);
        for (mv, xv) in mrow.iter_mut().zip(row.iter()) {
            *mv += *xv;
        }
    }
    for c in 0..k {
        if counts[c] > 0 {
            let inv = 1.0 / counts[c] as f64;
            for v in m.row_mut(c) {
                *v *= inv;
            }
        }
    }
    m
}

/// Nearest-centroid serving model in **input space**.
///
/// Two uses:
/// - plain K-means: `centroids` are the fitted K-means centroids, so
///   `predict` on the training set reproduces fit labels exactly (the fit
///   itself ends with the same assignment);
/// - the transductive spectral baselines (exact SC, LSC, Nyström, RF
///   family, sampled kernel K-means): `centroids` are the per-cluster
///   input-space class means of the training partition — an
///   *approximation* used as the serving fallback, since those methods
///   have no native out-of-sample embedding.
pub struct CentroidModel {
    /// K×d centroids in input space.
    pub centroids: Mat,
}

impl CentroidModel {
    pub fn new(centroids: Mat) -> CentroidModel {
        CentroidModel { centroids }
    }

    /// Build the transductive fallback from a fitted partition.
    pub fn from_labels(x: &Mat, labels: &[usize], k: usize) -> CentroidModel {
        CentroidModel { centroids: class_means(x, labels, k) }
    }

    fn check_dim(&self, x: &Mat) -> Result<(), ScrbError> {
        if x.cols != self.centroids.cols {
            return Err(ScrbError::invalid_input(format!(
                "expected {} input features, got {}",
                self.centroids.cols, x.cols
            )));
        }
        Ok(())
    }
}

impl FittedModel for CentroidModel {
    fn n_clusters(&self) -> usize {
        self.centroids.rows
    }

    fn input_dim(&self) -> usize {
        self.centroids.cols
    }

    /// The serving embedding of a centroid model *is* the input space.
    fn transform(&self, x: &Mat) -> Result<Mat, ScrbError> {
        self.check_dim(x)?;
        Ok(x.clone())
    }

    fn predict_batch(
        &self,
        x: &Mat,
        ws: &mut ServeWorkspace,
        out: &mut Vec<usize>,
    ) -> Result<(), ScrbError> {
        self.check_dim(x)?;
        out.resize(x.rows, 0);
        if x.rows == 0 {
            return Ok(());
        }
        ws.prepare(x.rows, 0);
        let centroids = &self.centroids;
        crate::util::threads::parallel_row_ranges_mut(
            &mut out[..],
            1,
            ws.bounds(),
            |_si, row0, chunk| {
                for (d, slot) in chunk.iter_mut().enumerate() {
                    *slot = nearest_centroid(centroids, x.row(row0 + d));
                }
            },
        );
        Ok(())
    }

    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any> {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_means_average_members() {
        let x = Mat::from_vec(4, 2, vec![0.0, 0.0, 2.0, 2.0, 4.0, 0.0, 0.0, 4.0]);
        let m = class_means(&x, &[0, 0, 1, 2], 4);
        assert_eq!(m.row(0), &[1.0, 1.0]);
        assert_eq!(m.row(1), &[4.0, 0.0]);
        assert_eq!(m.row(2), &[0.0, 4.0]);
        assert_eq!(m.row(3), &[0.0, 0.0]); // empty cluster stays zero
    }

    #[test]
    fn centroid_model_assigns_nearest() {
        let centroids = Mat::from_vec(3, 2, vec![0.0, 0.0, 10.0, 0.0, 0.0, 10.0]);
        let model = CentroidModel::new(centroids);
        let x = Mat::from_vec(3, 2, vec![1.0, 1.0, 9.0, -1.0, 2.0, 8.0]);
        let labels = model.predict(&x).unwrap();
        assert_eq!(labels, vec![0, 1, 2]);
        assert_eq!(model.n_clusters(), 3);
        assert_eq!(model.input_dim(), 2);
        // identity embedding
        let t = model.transform(&x).unwrap();
        assert_eq!(t.data, x.data);
        // dimension mismatch is a typed error
        let bad = Mat::zeros(2, 5);
        assert!(model.predict(&bad).is_err());
        assert!(model.transform(&bad).is_err());
        // no persistence for this kind
        assert!(model.save("/tmp/never.scrb").is_err());
    }

    #[test]
    fn ties_break_to_lowest_index() {
        let centroids = Mat::from_vec(2, 1, vec![-1.0, 1.0]);
        // 0.0 is equidistant: must go to centroid 0
        assert_eq!(nearest_centroid(&centroids, &[0.0]), 0);
    }

    #[test]
    fn workspace_reprovisions_lazily() {
        let mut ws = ServeWorkspace::new();
        ws.prepare(100, 4);
        let b1 = ws.bounds().to_vec();
        assert_eq!(*b1.first().unwrap(), 0);
        assert_eq!(*b1.last().unwrap(), 100);
        assert!(ws.stride() >= 4);
        // same shape: unchanged
        ws.prepare(100, 4);
        assert_eq!(ws.bounds(), &b1[..]);
        // wider embedding grows the stride, smaller batch shrinks bounds
        ws.prepare(10, 9);
        assert_eq!(*ws.bounds().last().unwrap(), 10);
        assert!(ws.stride() >= 9);
    }
}
