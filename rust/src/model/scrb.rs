//! `ScRbModel` — the fitted SC_RB artifact and its serving/persistence
//! paths.
//!
//! Fit (Algorithm 2) leaves behind three things:
//! - the **RB codebook** (grid widths/biases, seed, and the per-grid
//!   bin→column tables) — the data-independent feature map;
//! - the **singular triplets** of Ẑ, held as Σ plus the pre-folded
//!   projection `P = V·Σ⁻¹/√R` (D×K), so a point's embedding is the sum
//!   of the P rows of its occupied bins;
//! - the **K-means centroids** in the row-normalized embedding space.
//!
//! Out-of-sample prediction is then `R` table lookups + `R·K` adds + one
//! nearest-centroid scan — microseconds per point, no solver involved.
//! Because the training embedding differs from the serving one only by
//! the per-row scalar `d_i^{-1/2}` (which cancels under row
//! normalization), predicting the training set reproduces fit labels.
//!
//! # Persistence
//!
//! [`ScRbModel::save`]/[`ScRbModel::load`] use a versioned little-endian
//! binary format (magic `SCRBMODL`, version 3) with bounds-checked reads:
//! truncation, bad magic, or an unsupported version is a clean
//! [`ScrbError::Model`]. Since version 2 the image ends with an FNV-1a
//! checksum footer over the whole image, verified before any field is
//! parsed — so a truncated or bit-rotted file is *always* a typed error,
//! never a silently-wrong model. Version 3 adds a fixed 48-byte
//! [`UpdateState`] trailer (update/admission counters + drift EWMAs)
//! between the payload and the footer, persisting the online-maintenance
//! state across save/load; version-1 (no footer) and version-2 (no
//! trailer) files still load, with a default state. Grid parameters are
//! stored explicitly (widths + biases), not re-derived from the seed, so
//! a saved model does not depend on RNG stream stability across
//! versions.
//!
//! # Drift
//!
//! RB serving drops a point's contribution from any grid whose bin was
//! never seen at fit time. A little of that is normal at the data fringe;
//! a lot means the serving distribution has drifted off the training
//! distribution. Instead of dropping bins silently, every
//! `transform`/`predict`/`predict_batch` call counts its unseen-bin
//! lookups into a [`DriftMonitor`] ([`ScRbModel::drift_stats`]) and warns
//! on stderr when a single call's unseen rate exceeds
//! [`ScRbModel::unseen_warn`].

use super::persist::{split_checksummed, ByteReader, ByteWriter};
use super::{nearest_centroid, FittedModel, ServeWorkspace};
use crate::config::Kernel;
use crate::error::ScrbError;
use crate::linalg::Mat;
use crate::rb::{BinTable, Grid, RbCodebook};
use crate::util::threads::{parallel_row_ranges_mut, parallel_rows_mut};
use std::sync::atomic::{AtomicU64, Ordering};

const MAGIC: &[u8; 8] = b"SCRBMODL";
const VERSION: u32 = 3;

/// Byte length of the version-3 [`UpdateState`] trailer (six 8-byte
/// fields, written between the model payload and the checksum footer).
pub const UPDATE_TRAILER_BYTES: usize = 48;

/// Default per-call unseen-bin-rate threshold above which serving warns.
pub const DEFAULT_UNSEEN_WARN: f64 = 0.25;

/// At most one stderr warning per this many threshold-crossing calls: a
/// long-lived daemon seeing sustained drift must not turn every serving
/// call into a log line. The first offending call always warns; after
/// that, one warning (with cumulative counts) per `WARN_EVERY` offenders.
pub const WARN_EVERY: u64 = 64;

/// Persisted online-maintenance state (the SCRBMODL v3 trailer): how
/// much the model has been incrementally updated since fit, and where
/// the drift signals stood after the last update. Plain (non-atomic)
/// because [`ScRbModel::update`] takes `&mut self`; the serve daemon
/// reads it from its per-version model snapshot.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct UpdateState {
    /// `update()` calls absorbed (including gated no-op chunks).
    pub updates: u64,
    /// Data rows folded into the model across all updates.
    pub rows_absorbed: u64,
    /// Bins admitted after fit (global columns appended to the
    /// codebook/projection).
    pub bins_admitted: u64,
    /// Times the drift tracker escalated with `RefitNeeded`.
    pub refits_signaled: u64,
    /// EWMA of the per-update pre-admission unseen-bin rate.
    pub unseen_ewma: f64,
    /// EWMA of the per-update subspace residual ratio (chunk embedding
    /// energy the tracked subspace could not express).
    pub residual_ewma: f64,
}

/// Cumulative unseen-bin counters (the drift signal incremental updates
/// need). Atomic so `&self` serving paths can update them concurrently;
/// relaxed ordering — these are statistics, not synchronization.
#[derive(Debug, Default)]
pub struct DriftMonitor {
    /// Points served through embed-based paths.
    points: AtomicU64,
    /// Bin lookups performed (points × R).
    lookups: AtomicU64,
    /// Lookups that missed the codebook (bin unseen at fit time).
    unseen: AtomicU64,
    /// Serving calls whose per-call unseen rate crossed the warn
    /// threshold.
    over_threshold: AtomicU64,
    /// Warnings actually emitted to stderr (rate-limited: at most one per
    /// [`WARN_EVERY`] threshold-crossing calls).
    warnings: AtomicU64,
}

/// A point-in-time snapshot of a [`DriftMonitor`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DriftStats {
    pub points: u64,
    pub lookups: u64,
    pub unseen: u64,
    /// Calls whose unseen rate crossed the warn threshold.
    pub over_threshold: u64,
    /// Rate-limited warnings emitted so far.
    pub warnings: u64,
}

impl DriftStats {
    /// Fraction of bin lookups that missed the codebook (0 when nothing
    /// has been served).
    pub fn rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.unseen as f64 / self.lookups as f64
        }
    }
}

/// Raw base pointer to the per-worker embedding scratch; workers index
/// disjoint `stride`-sized regions by strip id (see `predict_batch`).
#[derive(Clone, Copy)]
struct ScratchPtr(*mut f64);
unsafe impl Send for ScratchPtr {}
unsafe impl Sync for ScratchPtr {}

/// Fitted SC_RB model: everything needed to embed and label points that
/// were never seen at fit time.
pub struct ScRbModel {
    /// RB feature map: grids + bin→column tables (Algorithm 1 state).
    pub codebook: RbCodebook,
    /// Kernel the pipeline was configured with (metadata).
    pub kernel: Kernel,
    /// Top-K singular values of Ẑ, descending.
    pub s: Vec<f64>,
    /// Projection `P = V·Σ⁻¹/√R` (D×K): a point's raw embedding is the
    /// sum of the rows of `P` indexed by its occupied bins.
    pub proj: Mat,
    /// K-means centroids in the row-normalized embedding space (K×K).
    pub centroids: Mat,
    /// Input-preprocessing frame the training data was normalized with
    /// (per-feature `(min, span)`), if any — serving batches must be
    /// brought into this frame, not normalized by their own statistics.
    pub norm: Option<(Vec<f64>, Vec<f64>)>,
    /// Cumulative unseen-bin counters across every serving call (runtime
    /// state, not persisted).
    pub drift: DriftMonitor,
    /// Per-call unseen-bin-rate threshold above which serving warns on
    /// stderr ([`DEFAULT_UNSEEN_WARN`] unless reconfigured; not
    /// persisted).
    pub unseen_warn: f64,
    /// Online-maintenance counters + drift EWMAs (persisted as the v3
    /// trailer; see [`crate::update`]).
    pub update_state: UpdateState,
}

impl ScRbModel {
    /// Embedding dimensionality K (columns of U the fit kept).
    pub fn embed_dim(&self) -> usize {
        self.proj.cols
    }

    /// Serving embedding of one point, written into `e` (length
    /// [`ScRbModel::embed_dim`]): sum of projection rows of the point's
    /// occupied bins, L2-normalized. Allocation-free.
    pub fn embed_into(&self, row: &[f64], e: &mut [f64]) {
        self.embed_into_counting(row, e);
    }

    /// [`ScRbModel::embed_into`], additionally returning how many of the
    /// point's R bins were unseen at fit time (and therefore contributed
    /// nothing) — the raw material of the drift counters.
    pub fn embed_into_counting(&self, row: &[f64], e: &mut [f64]) -> usize {
        debug_assert_eq!(row.len(), self.codebook.d_in);
        debug_assert_eq!(e.len(), self.embed_dim());
        e.fill(0.0);
        let mut missed = 0usize;
        for (grid, table) in self.codebook.grids.iter().zip(self.codebook.tables.iter()) {
            if let Some(c) = table.get(grid.bin_hash(row)) {
                let p = self.proj.row(c as usize);
                for (ej, pj) in e.iter_mut().zip(p.iter()) {
                    *ej += *pj;
                }
            } else {
                missed += 1;
            }
        }
        let norm = e.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm > 1e-300 {
            let inv = 1.0 / norm;
            for v in e.iter_mut() {
                *v *= inv;
            }
        }
        missed
    }

    /// Snapshot of the cumulative unseen-bin counters.
    pub fn drift_stats(&self) -> DriftStats {
        DriftStats {
            points: self.drift.points.load(Ordering::Relaxed),
            lookups: self.drift.lookups.load(Ordering::Relaxed),
            unseen: self.drift.unseen.load(Ordering::Relaxed),
            over_threshold: self.drift.over_threshold.load(Ordering::Relaxed),
            warnings: self.drift.warnings.load(Ordering::Relaxed),
        }
    }

    /// Fold one serving call's counts into the drift monitor and warn on
    /// stderr when this call's unseen rate crosses the threshold. The
    /// clean-data path (missed == 0) touches only three relaxed atomics —
    /// no formatting, no allocation. Warnings are rate-limited to one per
    /// [`WARN_EVERY`] threshold-crossing calls (the first always warns);
    /// the cumulative offender count is carried in the message so nothing
    /// is lost to the suppression.
    fn note_unseen(&self, points: u64, missed: u64) {
        let r = self.codebook.r as u64;
        self.drift.points.fetch_add(points, Ordering::Relaxed);
        self.drift.lookups.fetch_add(points * r, Ordering::Relaxed);
        if missed == 0 {
            return;
        }
        self.drift.unseen.fetch_add(missed, Ordering::Relaxed);
        let rate = missed as f64 / (points * r).max(1) as f64;
        if rate > self.unseen_warn {
            let prior = self.drift.over_threshold.fetch_add(1, Ordering::Relaxed);
            if prior % WARN_EVERY != 0 {
                return;
            }
            self.drift.warnings.fetch_add(1, Ordering::Relaxed);
            eprintln!(
                "warning: {missed} of {} bin lookups ({:.1}%) hit bins unseen at fit time \
                 (threshold {:.1}%) — the serving data may have drifted off the training \
                 distribution [{} call(s) over threshold so far; next warning after {} more]",
                points * r,
                rate * 100.0,
                self.unseen_warn * 100.0,
                prior + 1,
                WARN_EVERY
            );
        }
    }

    /// Label for an already-embedded point (nearest centroid).
    pub fn assign(&self, e: &[f64]) -> usize {
        nearest_centroid(&self.centroids, e)
    }

    fn check_dim(&self, x: &Mat) -> Result<(), ScrbError> {
        if x.cols != self.codebook.d_in {
            return Err(ScrbError::invalid_input(format!(
                "model expects {} input features, got {}",
                self.codebook.d_in, x.cols
            )));
        }
        Ok(())
    }

    /// Serialize to the versioned binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let cb = &self.codebook;
        debug_assert_eq!(self.s.len(), self.embed_dim(), "one σ per embedding column");
        debug_assert_eq!(self.centroids.cols, self.embed_dim(), "centroids live in embed space");
        debug_assert_eq!(self.proj.rows, cb.dim, "one projection row per bin");
        let mut w = ByteWriter::new();
        w.bytes(MAGIC);
        w.u32(VERSION);
        let (ktag, ksigma) = match self.kernel {
            Kernel::Laplacian { sigma } => (0u8, sigma),
            Kernel::Gaussian { sigma } => (1u8, sigma),
        };
        w.u8(ktag);
        w.f64(ksigma);
        w.u64(cb.seed);
        w.u32(cb.r as u32);
        w.u32(cb.d_in as u32);
        w.u64(cb.dim as u64);
        w.u32(self.embed_dim() as u32);
        w.u32(self.centroids.rows as u32);
        w.f64(cb.sigma);
        match &self.norm {
            None => w.u8(0),
            Some((min, span)) => {
                debug_assert_eq!(min.len(), cb.d_in);
                debug_assert_eq!(span.len(), cb.d_in);
                w.u8(1);
                w.f64_slice(min);
                w.f64_slice(span);
            }
        }
        w.f64_slice(&self.s);
        for g in &cb.grids {
            w.f64_slice(&g.widths);
            w.f64_slice(&g.biases);
        }
        for t in &cb.tables {
            w.u32(t.len() as u32);
            // canonical entry order: ascending column = the first-seen
            // order the tables were built in. Re-inserting in this order
            // at the same capacity reproduces the exact probe layout, so
            // save → load → save is byte-stable — which is what lets the
            // streamed-fit bit-exactness contract be checked on the
            // serialized artifact.
            let mut entries: Vec<(u64, u32)> = t.iter().collect();
            entries.sort_unstable_by_key(|&(_, col)| col);
            for (hash, col) in entries {
                w.u64(hash);
                w.u32(col);
            }
        }
        w.f64_slice(&self.proj.data);
        w.f64_slice(&self.centroids.data);
        // v3: fixed 48-byte update-state trailer (counters + drift EWMAs)
        let st = &self.update_state;
        w.u64(st.updates);
        w.u64(st.rows_absorbed);
        w.u64(st.bins_admitted);
        w.u64(st.refits_signaled);
        w.f64(st.unseen_ewma);
        w.f64(st.residual_ewma);
        // v2+: FNV-1a checksum footer over everything above (magic and
        // version included)
        w.finish_with_checksum()
    }

    /// Deserialize from the versioned binary format (v3 with update
    /// trailer + checksum footer, v2 with footer only, or legacy v1 with
    /// neither).
    pub fn from_bytes(bytes: &[u8]) -> Result<ScRbModel, ScrbError> {
        // magic + version are peeked outside the checksum machinery: the
        // version decides whether a footer exists at all
        let mut peek = ByteReader::new(bytes);
        if peek.bytes(8)? != &MAGIC[..] {
            return Err(ScrbError::model("not an scrb model file (bad magic)"));
        }
        let version = peek.u32()?;
        let payload = match version {
            1 => bytes,
            2 | VERSION => split_checksummed(bytes).ok_or_else(|| {
                ScrbError::model("checksum mismatch: the model file is corrupt or truncated")
            })?,
            other => {
                return Err(ScrbError::model(format!(
                    "unsupported model version {other} (this build reads versions 1-{VERSION})"
                )))
            }
        };
        let mut r = ByteReader::new(payload);
        r.bytes(8)?;
        r.u32()?;
        let ktag = r.u8()?;
        let ksigma = r.f64()?;
        let kernel = match ktag {
            0 => Kernel::Laplacian { sigma: ksigma },
            1 => Kernel::Gaussian { sigma: ksigma },
            other => return Err(ScrbError::model(format!("unknown kernel tag {other}"))),
        };
        let seed = r.u64()?;
        let nr = r.u32()? as usize;
        let d_in = r.u32()? as usize;
        let dim = r.u64()? as usize;
        let k_embed = r.u32()? as usize;
        let k_clusters = r.u32()? as usize;
        let sigma = r.f64()?;
        // Sanity caps: a corrupt header must not drive huge allocations.
        if nr == 0 || nr > 1 << 24 || d_in == 0 || d_in > 1 << 24 {
            return Err(ScrbError::model(format!("implausible header: r={nr} d_in={d_in}")));
        }
        if k_embed == 0 || k_embed > 1 << 16 || k_clusters == 0 || k_clusters > 1 << 16 {
            return Err(ScrbError::model(format!(
                "implausible header: k_embed={k_embed} k_clusters={k_clusters}"
            )));
        }
        if dim >= u32::MAX as usize || dim > (1usize << 40) / k_embed.max(1) {
            return Err(ScrbError::model(format!("implausible feature dimension D={dim}")));
        }
        let norm = match r.u8()? {
            0 => None,
            1 => {
                let min = r.f64_vec(d_in)?;
                let span = r.f64_vec(d_in)?;
                if min.iter().chain(span.iter()).any(|v| !v.is_finite())
                    || span.iter().any(|&v| v == 0.0)
                {
                    return Err(ScrbError::model(
                        "normalization parameters must be finite with non-zero spans",
                    ));
                }
                Some((min, span))
            }
            other => return Err(ScrbError::model(format!("unknown normalization tag {other}"))),
        };
        let s = r.f64_vec(k_embed)?;
        let mut grids = Vec::with_capacity(nr);
        for _ in 0..nr {
            let widths = r.f64_vec(d_in)?;
            let biases = r.f64_vec(d_in)?;
            if widths.iter().any(|&w| w <= 0.0 || !w.is_finite()) {
                return Err(ScrbError::model("grid widths must be positive and finite"));
            }
            if biases.iter().any(|&b| !b.is_finite()) {
                return Err(ScrbError::model("grid biases must be finite"));
            }
            grids.push(Grid::from_params(widths, biases));
        }
        let mut tables = Vec::with_capacity(nr);
        let mut total_bins = 0usize;
        for _ in 0..nr {
            let n = r.u32()? as usize;
            total_bins += n;
            if total_bins > dim {
                return Err(ScrbError::model(format!(
                    "bin tables hold more than D={dim} entries"
                )));
            }
            let mut t = BinTable::with_capacity(n);
            for _ in 0..n {
                let hash = r.u64()?;
                let col = r.u32()?;
                if col as usize >= dim {
                    return Err(ScrbError::model(format!(
                        "bin column {col} out of range for D={dim}"
                    )));
                }
                t.insert(hash, col);
            }
            tables.push(t);
        }
        if total_bins != dim {
            return Err(ScrbError::model(format!(
                "bin tables hold {total_bins} entries, header says D={dim}"
            )));
        }
        let proj = Mat::from_vec(dim, k_embed, r.f64_vec(dim * k_embed)?);
        let centroids = Mat::from_vec(k_clusters, k_embed, r.f64_vec(k_clusters * k_embed)?);
        // v3 trailer: update counters + drift EWMAs; earlier versions
        // carry none and load with a default (never-updated) state
        let update_state = if version >= 3 {
            let st = UpdateState {
                updates: r.u64()?,
                rows_absorbed: r.u64()?,
                bins_admitted: r.u64()?,
                refits_signaled: r.u64()?,
                unseen_ewma: r.f64()?,
                residual_ewma: r.f64()?,
            };
            if !(0.0..=1.0).contains(&st.unseen_ewma) || !(0.0..=1.0).contains(&st.residual_ewma) {
                return Err(ScrbError::model(format!(
                    "update-state EWMAs must be rates in [0, 1], got unseen={} residual={}",
                    st.unseen_ewma, st.residual_ewma
                )));
            }
            if st.bins_admitted > dim as u64 {
                return Err(ScrbError::model(format!(
                    "update state admits {} bins but the codebook only holds D={dim}",
                    st.bins_admitted
                )));
            }
            st
        } else {
            UpdateState::default()
        };
        if r.remaining() != 0 {
            return Err(ScrbError::model(format!(
                "{} trailing bytes after model payload",
                r.remaining()
            )));
        }
        let codebook = RbCodebook { r: nr, d_in, sigma, seed, dim, grids, tables };
        Ok(ScRbModel {
            codebook,
            kernel,
            s,
            proj,
            centroids,
            norm,
            drift: DriftMonitor::default(),
            unseen_warn: DEFAULT_UNSEEN_WARN,
            update_state,
        })
    }

    /// Load a model saved by [`ScRbModel::save`]. Every failure — missing
    /// file, truncation, checksum mismatch, bad magic — names `path`, so
    /// a CLI user staring at "corrupt model" knows *which* file is bad.
    pub fn load(path: &str) -> Result<ScRbModel, ScrbError> {
        let bytes = std::fs::read(path).map_err(|e| ScrbError::io(path, e))?;
        ScRbModel::from_bytes(&bytes).map_err(|e| match e {
            ScrbError::Model(m) => ScrbError::model(format!("{path}: {m}")),
            other => other,
        })
    }

    /// Fit SC_RB out-of-core: two chunked passes over `reader` (stats,
    /// then block-wise featurization) with resident input memory bounded
    /// by the reader's `chunk_rows`. On the same data and seed the
    /// returned model is **byte-identical** to the in-memory fit's — see
    /// [`crate::stream`] for the pipeline and its memory bound.
    pub fn fit_streaming(
        env: &crate::cluster::Env,
        reader: &mut dyn crate::stream::ChunkReader,
        opts: &crate::stream::StreamOpts,
    ) -> Result<crate::stream::StreamFit, ScrbError> {
        crate::stream::fit_streaming(env, reader, opts)
    }
}

impl FittedModel for ScRbModel {
    fn n_clusters(&self) -> usize {
        self.centroids.rows
    }

    fn input_dim(&self) -> usize {
        self.codebook.d_in
    }

    fn set_input_norm(&mut self, min: Vec<f64>, span: Vec<f64>) {
        assert_eq!(min.len(), self.codebook.d_in, "one min per input feature");
        assert_eq!(span.len(), self.codebook.d_in, "one span per input feature");
        assert!(
            span.iter().all(|&s| s.is_finite() && s != 0.0),
            "spans must be finite and non-zero"
        );
        self.norm = Some((min, span));
    }

    fn input_norm(&self) -> Option<(&[f64], &[f64])> {
        self.norm.as_ref().map(|(m, s)| (m.as_slice(), s.as_slice()))
    }

    /// Row-normalized spectral embedding rows `z·V·Σ⁻¹/‖·‖` (N×K) — the
    /// space the fit's K-means ran in (the fit itself calls this, so
    /// training rows and serving rows go through the identical path).
    fn transform(&self, x: &Mat) -> Result<Mat, ScrbError> {
        self.check_dim(x)?;
        let k = self.embed_dim();
        let mut m = Mat::zeros(x.rows, k);
        if x.rows == 0 || k == 0 {
            return Ok(m);
        }
        // each output row doubles as the scratch buffer embed_into fills
        let missed = AtomicU64::new(0);
        parallel_rows_mut(&mut m.data, k, |row0, chunk| {
            let mut local = 0usize;
            for (d, row) in chunk.chunks_mut(k).enumerate() {
                local += self.embed_into_counting(x.row(row0 + d), row);
            }
            if local > 0 {
                missed.fetch_add(local as u64, Ordering::Relaxed);
            }
        });
        self.note_unseen(x.rows as u64, missed.load(Ordering::Relaxed));
        Ok(m)
    }

    fn predict_batch(
        &self,
        x: &Mat,
        ws: &mut ServeWorkspace,
        out: &mut Vec<usize>,
    ) -> Result<(), ScrbError> {
        self.check_dim(x)?;
        let n = x.rows;
        out.resize(n, 0);
        if n == 0 {
            return Ok(());
        }
        let k = self.embed_dim();
        ws.prepare(n, k);
        let stride = ws.stride();
        let scratch = ScratchPtr(ws.scratch_ptr());
        let missed = AtomicU64::new(0);
        parallel_row_ranges_mut(&mut out[..], 1, ws.bounds(), |si, row0, chunk| {
            // SAFETY: strip `si` is the only worker using the scratch
            // region [si·stride, si·stride + k); strips are disjoint and
            // the workspace outlives the scoped-thread join.
            let e = unsafe { std::slice::from_raw_parts_mut(scratch.0.add(si * stride), k) };
            let mut local = 0usize;
            for (d, slot) in chunk.iter_mut().enumerate() {
                local += self.embed_into_counting(x.row(row0 + d), e);
                *slot = nearest_centroid(&self.centroids, e);
            }
            if local > 0 {
                missed.fetch_add(local as u64, Ordering::Relaxed);
            }
        });
        self.note_unseen(n as u64, missed.load(Ordering::Relaxed));
        Ok(())
    }

    fn save(&self, path: &str) -> Result<(), ScrbError> {
        std::fs::write(path, self.to_bytes()).map_err(|e| ScrbError::io(path, e))
    }

    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any> {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rb::rb_features_with_codebook;
    use crate::util::rng::Pcg;

    /// Tiny hand-rolled model over random RB features (no solver): the
    /// projection is an arbitrary D×k matrix, centroids arbitrary — enough
    /// to pin serialization and the serving plumbing.
    fn toy_model(n: usize, r: usize, k: usize, seed: u64) -> (ScRbModel, Mat) {
        let mut rng = Pcg::seed(seed);
        let d_in = 3;
        let x = Mat::from_vec(n, d_in, (0..n * d_in).map(|_| rng.f64()).collect());
        let (rb, codebook) = rb_features_with_codebook(&x, r, 0.5, seed ^ 0xab);
        let dim = rb.dim();
        let proj = Mat::from_vec(dim, k, (0..dim * k).map(|_| rng.range_f64(-1.0, 1.0)).collect());
        let centroids =
            Mat::from_vec(2, k, (0..2 * k).map(|_| rng.range_f64(-1.0, 1.0)).collect());
        let model = ScRbModel {
            codebook,
            kernel: Kernel::Laplacian { sigma: 0.5 },
            s: (0..k).map(|j| 1.0 / (j + 1) as f64).collect(),
            proj,
            centroids,
            norm: None,
            drift: DriftMonitor::default(),
            unseen_warn: DEFAULT_UNSEEN_WARN,
            update_state: UpdateState::default(),
        };
        (model, x)
    }

    #[test]
    fn bytes_roundtrip_exactly() {
        let (model, x) = toy_model(60, 8, 4, 7);
        let bytes = model.to_bytes();
        let back = ScRbModel::from_bytes(&bytes).unwrap();
        // canonical serialization: load → save reproduces the bytes
        assert_eq!(back.to_bytes(), bytes);
        assert_eq!(back.s, model.s);
        assert_eq!(back.proj.data, model.proj.data);
        assert_eq!(back.centroids.data, model.centroids.data);
        assert_eq!(back.codebook.dim, model.codebook.dim);
        assert_eq!(back.codebook.seed, model.codebook.seed);
        assert_eq!(back.kernel, model.kernel);
        // identical serving behaviour, bit for bit
        let a = model.transform(&x).unwrap();
        let b = back.transform(&x).unwrap();
        assert_eq!(a.data, b.data);
        assert_eq!(model.predict(&x).unwrap(), back.predict(&x).unwrap());

        // a stored normalization frame round-trips and is applied
        let mut with_norm = ScRbModel::from_bytes(&bytes).unwrap();
        with_norm.set_input_norm(vec![0.5; 3], vec![2.0; 3]);
        let back2 = ScRbModel::from_bytes(&with_norm.to_bytes()).unwrap();
        assert_eq!(back2.norm, with_norm.norm);
        let mut batch = Mat::from_vec(1, 3, vec![0.5, 2.5, -1.5]);
        back2.apply_input_norm(&mut batch);
        assert_eq!(batch.data, vec![0.0, 1.0, -1.0]);
    }

    #[test]
    fn corrupt_and_truncated_files_fail_cleanly() {
        let (model, _) = toy_model(40, 4, 3, 9);
        let bytes = model.to_bytes();
        // truncations at every interesting boundary
        for cut in [0usize, 4, 8, 12, 40, bytes.len() / 2, bytes.len() - 1] {
            assert!(ScRbModel::from_bytes(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        // bad magic
        let mut bad = bytes.clone();
        bad[0] ^= 0xff;
        assert!(ScRbModel::from_bytes(&bad).is_err());
        // unsupported version
        let mut bad = bytes.clone();
        bad[8] = 0xee;
        assert!(ScRbModel::from_bytes(&bad).is_err());
        // trailing garbage
        let mut bad = bytes.clone();
        bad.push(0);
        assert!(ScRbModel::from_bytes(&bad).is_err());
    }

    #[test]
    fn predict_batch_matches_predict_and_transform() {
        let (model, x) = toy_model(80, 6, 3, 11);
        let one_by_one = model.predict(&x).unwrap();
        let mut ws = ServeWorkspace::new();
        let mut batch = Vec::new();
        model.predict_batch(&x, &mut ws, &mut batch).unwrap();
        assert_eq!(one_by_one, batch);
        // labels agree with an explicit transform + assign
        let t = model.transform(&x).unwrap();
        for i in 0..x.rows {
            assert_eq!(batch[i], model.assign(t.row(i)));
        }
        // workspace reuse across batch sizes
        let small = x.row_block(0, 5);
        model.predict_batch(&small, &mut ws, &mut batch).unwrap();
        assert_eq!(batch.len(), 5);
        assert_eq!(&batch[..], &one_by_one[..5]);
    }

    #[test]
    fn dimension_mismatch_is_an_error() {
        let (model, _) = toy_model(30, 4, 3, 13);
        let bad = Mat::zeros(5, 7);
        assert!(model.predict(&bad).is_err());
        assert!(model.transform(&bad).is_err());
        let mut ws = ServeWorkspace::new();
        let mut out = Vec::new();
        assert!(model.predict_batch(&bad, &mut ws, &mut out).is_err());
    }

    #[test]
    fn load_missing_file_is_io_error() {
        let e = ScRbModel::load("/no/such/model.scrb").unwrap_err();
        assert!(matches!(e, ScrbError::Io { .. }));
    }

    #[test]
    fn v1_and_v2_files_still_load() {
        let (model, x) = toy_model(50, 5, 3, 17);
        let v3 = model.to_bytes();
        // a v1 image is the payload without trailer or footer
        let strip = UPDATE_TRAILER_BYTES + 8;
        let mut v1 = v3[..v3.len() - strip].to_vec();
        v1[8..12].copy_from_slice(&1u32.to_le_bytes());
        let back = ScRbModel::from_bytes(&v1).unwrap();
        assert_eq!(back.transform(&x).unwrap().data, model.transform(&x).unwrap().data);
        assert_eq!(back.update_state, UpdateState::default());
        // saving a legacy load re-emits the current (v3) format
        assert_eq!(back.to_bytes(), v3);
        // a v2 image adds the checksum footer but no update trailer
        let mut v2 = v3[..v3.len() - strip].to_vec();
        v2[8..12].copy_from_slice(&2u32.to_le_bytes());
        let sum = crate::util::fnv::fnv64(&v2);
        v2.extend_from_slice(&sum.to_le_bytes());
        let back2 = ScRbModel::from_bytes(&v2).unwrap();
        assert_eq!(back2.transform(&x).unwrap().data, model.transform(&x).unwrap().data);
        assert_eq!(back2.to_bytes(), v3);
        // a v3 image relabeled v1 leaves trailer + footer dangling → typed error
        let mut relabeled = v3.clone();
        relabeled[8..12].copy_from_slice(&1u32.to_le_bytes());
        assert!(matches!(ScRbModel::from_bytes(&relabeled), Err(ScrbError::Model(_))));
        // a v3 image relabeled v2 fails the checksum (version is covered)
        let mut relabeled = v3.clone();
        relabeled[8..12].copy_from_slice(&2u32.to_le_bytes());
        assert!(matches!(ScRbModel::from_bytes(&relabeled), Err(ScrbError::Model(_))));
    }

    #[test]
    fn update_state_round_trips_in_the_v3_trailer() {
        let (mut model, _) = toy_model(40, 4, 3, 31);
        model.update_state = UpdateState {
            updates: 7,
            rows_absorbed: 4096,
            bins_admitted: 5,
            refits_signaled: 1,
            unseen_ewma: 0.125,
            residual_ewma: 0.5,
        };
        // bins_admitted must stay plausible against the header D
        assert!(model.update_state.bins_admitted <= model.codebook.dim as u64);
        let bytes = model.to_bytes();
        let back = ScRbModel::from_bytes(&bytes).unwrap();
        assert_eq!(back.update_state, model.update_state);
        assert_eq!(back.to_bytes(), bytes);
        // corrupt EWMAs are typed errors even when the checksum is fixed up
        let mut bad = bytes[..bytes.len() - 8].to_vec();
        let at = bad.len() - 16; // unseen_ewma field
        bad[at..at + 8].copy_from_slice(&2.5f64.to_le_bytes());
        let sum = crate::util::fnv::fnv64(&bad);
        bad.extend_from_slice(&sum.to_le_bytes());
        assert!(matches!(ScRbModel::from_bytes(&bad), Err(ScrbError::Model(_))));
    }

    #[test]
    fn drift_monitor_counts_unseen_bins() {
        let (model, x) = toy_model(60, 8, 4, 19);
        // training rows hit only bins the codebook saw: zero unseen
        model.transform(&x).unwrap();
        let s = model.drift_stats();
        assert_eq!(s.points, 60);
        assert_eq!(s.lookups, 60 * 8);
        assert_eq!(s.unseen, 0);
        assert_eq!(s.rate(), 0.0);
        // rows far outside the training range land in unseen bins
        let far = Mat::from_vec(2, 3, vec![1e3; 6]);
        model.transform(&far).unwrap();
        let s2 = model.drift_stats();
        assert_eq!(s2.points, 62);
        assert!(s2.unseen > 0, "far-out rows must miss the codebook");
        assert!(s2.rate() > 0.0 && s2.rate() <= 1.0);
        // predict_batch feeds the same counters
        let mut ws = ServeWorkspace::new();
        let mut out = Vec::new();
        model.predict_batch(&far, &mut ws, &mut out).unwrap();
        let s3 = model.drift_stats();
        assert_eq!(s3.points, 64);
        assert!(s3.unseen > s2.unseen, "misses accumulate across calls");
    }

    #[test]
    fn drift_warning_is_rate_limited() {
        let (model, x) = toy_model(60, 8, 4, 23);
        // clean calls never count as offenders
        model.transform(&x).unwrap();
        let s = model.drift_stats();
        assert_eq!((s.over_threshold, s.warnings), (0, 0));
        // every far-out call crosses the threshold (all R bins miss), but
        // only one in WARN_EVERY emits: calls 1, 65, 129, 193 of 200
        let far = Mat::from_vec(1, 3, vec![1e3; 3]);
        for _ in 0..200 {
            model.transform(&far).unwrap();
        }
        let s = model.drift_stats();
        assert_eq!(s.over_threshold, 200);
        assert_eq!(s.warnings, 200_u64.div_ceil(WARN_EVERY));
    }

    #[test]
    fn load_corrupt_file_error_names_the_path() {
        let (model, _) = toy_model(40, 4, 3, 29);
        let dir = std::env::temp_dir().join(format!("scrb_load_path_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("corrupt.scrb");
        let mut bytes = model.to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let e = ScRbModel::load(path.to_str().unwrap()).unwrap_err();
        assert!(matches!(e, ScrbError::Model(_)));
        let msg = e.to_string();
        assert!(msg.contains("corrupt.scrb"), "error must name the file: {msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
