//! Random Binning feature-matrix generation (Algorithm 1, lines 3–5).
//!
//! Produces the large sparse binary matrix Z ∈ R^{N×D}: row i has exactly
//! one non-zero per grid (the bin x_i falls in), value 1/√R. D is the total
//! number of *non-empty* bins across all R grids — data-dependent, as in
//! the paper (D grows with both R and 1/σ).
//!
//! Generation parallelizes over grids (the paper §5.4 uses 4 threads the
//! same way): each grid hashes every point's bin tuple to a local bin id;
//! a prefix sum over per-grid bin counts then gives disjoint global column
//! ranges, so assembly needs *no* sorting — within a row, grid order is
//! column order.
//!
//! The output substrate is [`EllRb`]: phase 2 already produces the flat
//! n×R index layout EllRb stores verbatim (zero-copy), the shared value
//! 1/√R becomes the per-row scale vector, and construction precomputes the
//! transpose layout the eigensolver's Ẑᵀ·B products run on. Baselines that
//! need general CSR go through [`EllRb::to_csr`].

use super::codebook::{BinTable, RbCodebook};
use super::grid::{sample_grids, Grid};
use crate::linalg::Mat;
use crate::sparse::EllRb;
use crate::util::threads::parallel_chunks_mut;
use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Identity hasher for keys that are already well-mixed 64-bit hashes
/// (`Grid::bin_hash` output). Skips SipHash in the phase-1 bin dictionary —
/// measured ~1.35× on RB generation (EXPERIMENTS.md §Perf iteration 2).
#[derive(Default)]
pub struct IdentityHasher(u64);

impl Hasher for IdentityHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        // only u64 keys are ever hashed here
        let mut buf = [0u8; 8];
        buf[..bytes.len().min(8)].copy_from_slice(&bytes[..bytes.len().min(8)]);
        self.0 = u64::from_le_bytes(buf);
    }
    #[inline]
    fn write_u64(&mut self, v: u64) {
        self.0 = v;
    }
}

type BinDict = HashMap<u64, u32, BuildHasherDefault<IdentityHasher>>;

/// Output of RB generation.
pub struct RbFeatures {
    /// Sparse feature matrix Z, N×D, nnz = N·R, all values 1/√R — on the
    /// fixed-stride [`EllRb`] substrate the solver hot path consumes.
    pub z: EllRb,
    /// Number of grids R.
    pub r: usize,
    /// Per-grid number of non-empty bins.
    pub bins_per_grid: Vec<usize>,
    /// κ estimate (Definition 1): E_grid[1 / max_b ν_b], the expected
    /// lower bound on non-empty bins per grid; drives the Theorem 1 rate.
    pub kappa: f64,
}

impl RbFeatures {
    /// Total feature dimension D.
    pub fn dim(&self) -> usize {
        self.z.cols
    }
}

/// Per-grid binning result (phase 1).
struct GridBins {
    /// Local bin id for every point, in [0, n_bins).
    local: Vec<u32>,
    n_bins: usize,
    /// Largest collision count max_b |{i : bin(x_i)=b}|.
    max_count: usize,
    /// Bin hash of each local id, in first-seen (= id) order — retained so
    /// a fit can build the out-of-sample [`RbCodebook`] tables in a
    /// *deterministic* insertion order. The streaming ingestion path
    /// (`crate::stream`) rebuilds its codebook the same way, which is what
    /// makes a streamed fit serialize bit-identically to a batch fit.
    hashes: Vec<u64>,
}

fn bin_one_grid(x: &Mat, grid: &Grid) -> GridBins {
    let n = x.rows;
    let mut dict: BinDict = BinDict::with_capacity_and_hasher(n / 2, Default::default());
    let mut counts: Vec<usize> = Vec::new();
    let mut hashes: Vec<u64> = Vec::new();
    let mut local = Vec::with_capacity(n);
    for i in 0..n {
        let h = grid.bin_hash(x.row(i));
        let next = dict.len() as u32;
        let id = *dict.entry(h).or_insert(next);
        if id as usize == counts.len() {
            counts.push(0);
            hashes.push(h);
        }
        counts[id as usize] += 1;
        local.push(id);
    }
    GridBins {
        local,
        n_bins: dict.len(),
        max_count: counts.iter().copied().max().unwrap_or(0),
        hashes,
    }
}

/// Generate RB features for data `x` with `r` grids and Laplacian-kernel
/// bandwidth `sigma`. Deterministic in `seed`.
pub fn rb_features(x: &Mat, r: usize, sigma: f64, seed: u64) -> RbFeatures {
    rb_features_impl(x, r, sigma, seed, false).0
}

/// [`rb_features`] that additionally returns the [`RbCodebook`] — the
/// grids plus the bin→global-column maps — so a fitted model can project
/// out-of-sample points into the same feature columns (the serving path).
/// The feature matrix is identical to the plain call.
pub fn rb_features_with_codebook(
    x: &Mat,
    r: usize,
    sigma: f64,
    seed: u64,
) -> (RbFeatures, RbCodebook) {
    let (features, codebook) = rb_features_impl(x, r, sigma, seed, true);
    (features, codebook.expect("codebook requested"))
}

fn rb_features_impl(
    x: &Mat,
    r: usize,
    sigma: f64,
    seed: u64,
    keep_codebook: bool,
) -> (RbFeatures, Option<RbCodebook>) {
    assert!(r >= 1, "need at least one grid");
    let n = x.rows;
    let grids = sample_grids(r, x.cols, sigma, seed);

    // Phase 1 (parallel over grids): hash every point to its per-grid bin.
    let mut per_grid: Vec<Option<GridBins>> = (0..r).map(|_| None).collect();
    parallel_chunks_mut(&mut per_grid, crate::util::threads::num_threads(), |start, slot| {
        for (k, s) in slot.iter_mut().enumerate() {
            *s = Some(bin_one_grid(x, &grids[start + k]));
        }
    });
    let per_grid: Vec<GridBins> = per_grid.into_iter().map(|o| o.unwrap()).collect();

    // Global column offsets: grid j owns columns [off_j, off_j + n_bins_j).
    let mut offsets = Vec::with_capacity(r + 1);
    offsets.push(0usize);
    for g in &per_grid {
        offsets.push(offsets.last().unwrap() + g.n_bins);
    }
    let d_total = *offsets.last().unwrap();
    assert!(d_total < u32::MAX as usize, "feature dimension overflows u32");

    // κ (Definition 1): κ_δ = 1/ν_δ with ν_δ = max_b count_b / N.
    let kappa = per_grid
        .iter()
        .map(|g| if g.max_count > 0 { n as f64 / g.max_count as f64 } else { 1.0 })
        .sum::<f64>()
        / r as f64;

    // Phase 2 (parallel over rows): assemble the flat n×R EllRb index
    // layout directly. Row i's entries are (offsets[j] + local[j][i]) for
    // j = 0..R — ascending in j, hence already column-sorted.
    let val = 1.0 / (r as f64).sqrt();
    let mut indices: Vec<u32> = vec![0; n * r];
    parallel_chunks_mut(&mut indices, crate::util::threads::num_threads(), |start, chunk| {
        // chunk covers flat positions [start, start+len); position p = i*r + j.
        // One div/mod per chunk to seed the (i, j) cursors, then row-major
        // running offsets — the inner loop is div-free.
        let mut i = start / r;
        let mut j = start % r;
        for slot in chunk.iter_mut() {
            *slot = (offsets[j] + per_grid[j].local[i] as usize) as u32;
            j += 1;
            if j == r {
                j = 0;
                i += 1;
            }
        }
    });
    let z = EllRb::new(n, d_total, r, indices, vec![val; n]);

    // The codebook rehomes each grid's bin dictionary into a flat probe
    // table keyed by the raw bin hash, with values shifted to *global*
    // columns — exactly the lookup a new point's features need. Entries
    // are inserted in first-seen (= local id) order at a capacity fixed by
    // the final bin count, so the slot layout — and hence the serialized
    // model — is a pure function of the binning, not of dictionary
    // internals (the streaming path reproduces it exactly).
    let codebook = keep_codebook.then(|| {
        let tables: Vec<BinTable> = per_grid
            .iter()
            .enumerate()
            .map(|(j, g)| codebook_table(&g.hashes, offsets[j]))
            .collect();
        RbCodebook { r, d_in: x.cols, sigma, seed, dim: d_total, grids, tables }
    });

    let features =
        RbFeatures { z, r, bins_per_grid: per_grid.iter().map(|g| g.n_bins).collect(), kappa };
    (features, codebook)
}

/// Build one grid's serving [`BinTable`] from its first-seen bin hashes:
/// capacity sized for the final bin count, entries inserted in local-id
/// order with columns shifted by the grid's global offset. Shared by the
/// batch path above and the streaming featurizer (`crate::stream`) — both
/// must produce byte-identical codebooks for the same binning.
pub(crate) fn codebook_table(hashes: &[u64], offset: usize) -> BinTable {
    let mut table = BinTable::with_capacity(hashes.len());
    for (local, &h) in hashes.iter().enumerate() {
        table.insert(h, (offset + local) as u32);
    }
    table
}

/// Exact (dense) Laplacian-kernel Gram matrix for comparison in tests and
/// the convergence-theory driver: K_ij = exp(−‖x_i − x_j‖₁ / σ).
pub fn exact_laplacian_gram(x: &Mat, sigma: f64) -> Mat {
    let n = x.rows;
    let mut k = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let v = (-crate::linalg::l1dist(x.row(i), x.row(j)) / sigma).exp();
            k.set(i, j, v);
            k.set(j, i, v);
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    fn rand_data(rng: &mut Pcg, n: usize, d: usize) -> Mat {
        Mat::from_vec(n, d, (0..n * d).map(|_| rng.f64()).collect())
    }

    #[test]
    fn shape_and_sparsity_invariants() {
        let mut rng = Pcg::seed(91);
        let x = rand_data(&mut rng, 200, 5);
        let r = 32;
        let rb = rb_features(&x, r, 0.5, 7);
        assert_eq!(rb.z.rows, 200);
        assert_eq!(rb.z.nnz(), 200 * r); // exactly R non-zeros per row
        for i in 0..200 {
            assert_eq!(rb.z.row_indices(i).len(), r);
        }
        // all values 1/sqrt(R) — one shared scale per row on EllRb
        let v = 1.0 / (r as f64).sqrt();
        assert!(rb.z.scale.iter().all(|&x| (x - v).abs() < 1e-15));
        // column indices strictly increasing within each row (grid blocks)
        for i in 0..200 {
            let idx = rb.z.row_indices(i);
            for w in idx.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
        // D = sum of per-grid bins
        assert_eq!(rb.dim(), rb.bins_per_grid.iter().sum::<usize>());
        assert!(rb.kappa >= 1.0);
    }

    #[test]
    fn gram_approximates_kernel() {
        // E[Z Zᵀ]_ij = k(x_i, x_j); check Frobenius-relative error shrinks.
        let mut rng = Pcg::seed(92);
        let x = rand_data(&mut rng, 60, 3);
        let sigma = 1.0;
        let exact = exact_laplacian_gram(&x, sigma);
        let mut errs = Vec::new();
        for &r in &[16usize, 256] {
            let rb = rb_features(&x, r, sigma, 11);
            let approx = rb.z.gram_dense();
            errs.push(approx.sub(&exact).frob_norm() / exact.frob_norm());
        }
        assert!(errs[1] < errs[0] * 0.5, "R=16 err {} vs R=256 err {}", errs[0], errs[1]);
        assert!(errs[1] < 0.12, "R=256 err too large: {}", errs[1]);
    }

    #[test]
    fn diag_is_one() {
        // Each row of Z has R entries of 1/√R ⇒ (ZZᵀ)_ii = 1 = k(x,x).
        let mut rng = Pcg::seed(93);
        let x = rand_data(&mut rng, 30, 4);
        let rb = rb_features(&x, 64, 2.0, 3);
        let g = rb.z.gram_dense();
        for i in 0..30 {
            assert!((g.at(i, i) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let mut rng = Pcg::seed(94);
        let x = rand_data(&mut rng, 50, 3);
        let a = rb_features(&x, 16, 1.0, 5);
        let b = rb_features(&x, 16, 1.0, 5);
        assert_eq!(a.z, b.z);
        let c = rb_features(&x, 16, 1.0, 6);
        assert_ne!(a.z, c.z);
    }

    #[test]
    fn codebook_reproduces_training_columns() {
        // For every training point and every grid, the codebook lookup
        // must return exactly the column the feature matrix assigned.
        let mut rng = Pcg::seed(96);
        let x = rand_data(&mut rng, 150, 4);
        let r = 24;
        let (rb, cb) = rb_features_with_codebook(&x, r, 0.6, 13);
        assert_eq!(cb.r, r);
        assert_eq!(cb.d_in, 4);
        assert_eq!(cb.dim, rb.dim());
        assert_eq!(cb.tables.iter().map(|t| t.len()).sum::<usize>(), rb.dim());
        for i in 0..150 {
            let row = x.row(i);
            let cols = rb.z.row_indices(i);
            for j in 0..r {
                assert_eq!(cb.lookup(j, row), Some(cols[j]), "point {i} grid {j}");
            }
            assert_eq!(cb.coverage(row), 1.0);
        }
        // a far-away point misses bins that were never occupied
        let far = vec![1e6; 4];
        assert!(cb.coverage(&far) < 1.0);
        // and the with-codebook path emits the identical feature matrix
        let plain = rb_features(&x, r, 0.6, 13);
        assert_eq!(plain.z, rb.z);
    }

    #[test]
    fn kappa_grows_with_smaller_sigma() {
        // Smaller σ → narrower bins → more non-empty bins per grid → larger κ.
        let mut rng = Pcg::seed(95);
        let x = rand_data(&mut rng, 300, 4);
        let wide = rb_features(&x, 32, 4.0, 9);
        let narrow = rb_features(&x, 32, 0.2, 9);
        assert!(
            narrow.kappa > wide.kappa,
            "narrow κ {} should exceed wide κ {}",
            narrow.kappa,
            wide.kappa
        );
        assert!(narrow.dim() > wide.dim());
    }
}
