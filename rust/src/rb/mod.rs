//! Random Binning features (the paper's Algorithm 1): random-grid sampling
//! and sparse feature-matrix generation, plus the κ estimator of
//! Definition 1 that drives the Theorem 1 convergence rate.
//!
//! The [`codebook`] submodule captures the *fitted* feature map — grid
//! parameters plus the bin→column tables discovered on the training set —
//! which is what makes RB's out-of-sample extension (`model::ScRbModel`)
//! a pure lookup: the map itself is data-independent (Algorithm 1 draws
//! grids from the kernel, not the data), so a new point bins into the
//! learned column space without refitting anything.

pub mod codebook;
pub mod features;
pub mod grid;

pub use codebook::{BinTable, RbCodebook};
pub use features::{
    exact_laplacian_gram, rb_features, rb_features_with_codebook, RbFeatures,
};
pub use grid::{sample_grids, Grid};
