//! Random Binning features (the paper's Algorithm 1): random-grid sampling
//! and sparse feature-matrix generation, plus the κ estimator of
//! Definition 1 that drives the Theorem 1 convergence rate.

pub mod features;
pub mod grid;

pub use features::{exact_laplacian_gram, rb_features, RbFeatures};
pub use grid::{sample_grids, Grid};
