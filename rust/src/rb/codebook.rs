//! RB codebook: the data-independent part of the fitted SC_RB model.
//!
//! Algorithm 1's feature map is defined entirely by (a) the R random grids
//! (widths ω and biases u, drawn from the kernel) and (b) the mapping from
//! *occupied* bins to global feature columns discovered on the training
//! set. Both are captured here so a fitted model can bin a never-seen
//! point and land it in exactly the columns the training matrix Z used —
//! the out-of-sample extension the serving path (`model::ScRbModel`)
//! builds on. Bins that were empty during fit have no column: a new point
//! falling in one simply contributes a zero feature, mirroring how the
//! training Z only materializes non-empty bins.
//!
//! The bin→column map is a flat open-addressing hash table ([`BinTable`]):
//! keys are the already well-mixed 64-bit `Grid::bin_hash` values, the
//! load factor is kept ≤ 0.5, and lookups are allocation-free — the
//! serving hot path does R probes per point.

use super::grid::Grid;

/// Sentinel marking an empty slot (column ids are capped below `u32::MAX`
/// at RB construction).
const EMPTY: u32 = u32::MAX;

/// Flat open-addressing map from a grid's bin hash to its global feature
/// column. Power-of-two capacity, linear probing, ≤ 0.5 load factor when
/// sized with [`BinTable::with_capacity`]; `insert` refuses to fill the
/// table completely (at least one empty slot always remains), so `get`
/// probes are guaranteed to terminate. [`BinTable::get_or_assign`] turns
/// the same table into a *growable* first-seen dictionary — the streaming
/// ingestion path uses it as the incrementally-grown phase-1 bin
/// dictionary (one per grid) that later chunks keep extending.
#[derive(Clone, Debug)]
pub struct BinTable {
    mask: usize,
    len: usize,
    keys: Vec<u64>,
    cols: Vec<u32>,
}

impl Default for BinTable {
    fn default() -> Self {
        BinTable::new()
    }
}

impl BinTable {
    /// Table sized for `n` occupied bins (capacity = next power of two
    /// ≥ 2n, so probe chains stay short).
    pub fn with_capacity(n: usize) -> BinTable {
        let cap = (n.max(1) * 2).next_power_of_two();
        BinTable { mask: cap - 1, len: 0, keys: vec![0; cap], cols: vec![EMPTY; cap] }
    }

    /// Empty growable table (see [`BinTable::get_or_assign`]); starts small
    /// and rehashes as bins accumulate, so the streaming phase-1
    /// dictionaries need no up-front bin count.
    pub fn new() -> BinTable {
        BinTable::with_capacity(8)
    }

    /// Look up `key`, assigning it the next dense id (`self.len()`) if it
    /// is absent — the streaming phase-1 dictionary operation: bin hashes
    /// map to first-seen local bin ids exactly like the batch path's
    /// `HashMap::entry(..).or_insert(len)`. Only an *insert* can grow the
    /// table (rehash to double capacity when the ≤ 0.5 load factor would
    /// be exceeded), so re-binning known bins — the streaming steady
    /// state — is strictly allocation-free.
    pub fn get_or_assign(&mut self, key: u64) -> u32 {
        let mut i = (key as usize) & self.mask;
        loop {
            let c = self.cols[i];
            if c == EMPTY {
                break;
            }
            if self.keys[i] == key {
                return c;
            }
            i = (i + 1) & self.mask;
        }
        // absent: make room if this insert would exceed the load factor,
        // then claim the slot
        if 2 * (self.len + 1) > self.cols.len() {
            self.grow();
            i = (key as usize) & self.mask;
            while self.cols[i] != EMPTY {
                i = (i + 1) & self.mask;
            }
        }
        let id = self.len as u32;
        debug_assert!(id != EMPTY, "bin id collides with the empty sentinel");
        self.keys[i] = key;
        self.cols[i] = id;
        self.len += 1;
        id
    }

    /// Double the slot count and rehash every occupied entry. Final slot
    /// layout depends only on the key set and the capacity (entries are
    /// reinserted in slot order), but growable tables are phase-1
    /// *dictionaries* — the serialized codebook tables are always rebuilt
    /// at a deterministic capacity in first-seen order, so growth history
    /// never leaks into a persisted model.
    fn grow(&mut self) {
        let new_cap = (self.cols.len() * 2).max(8);
        let old_keys = std::mem::replace(&mut self.keys, vec![0; new_cap]);
        let old_cols = std::mem::replace(&mut self.cols, vec![EMPTY; new_cap]);
        self.mask = new_cap - 1;
        for (k, c) in old_keys.into_iter().zip(old_cols.into_iter()) {
            if c == EMPTY {
                continue;
            }
            let mut i = (k as usize) & self.mask;
            while self.cols[i] != EMPTY {
                i = (i + 1) & self.mask;
            }
            self.keys[i] = k;
            self.cols[i] = c;
        }
    }

    /// Look up `key`, inserting it with the *caller-chosen* column `col`
    /// if absent; returns `(column, inserted)`. This is the post-fit
    /// *admission* operation: unlike [`BinTable::get_or_assign`] (whose
    /// dense first-seen ids are local to one growing dictionary), the
    /// caller supplies the next **global** column id, so a fitted
    /// codebook whose tables already hold global columns can keep
    /// growing after fit. Growth (rehash) happens only on an actual
    /// insert — looking up known bins stays allocation-free.
    pub fn get_or_insert(&mut self, key: u64, col: u32) -> (u32, bool) {
        debug_assert!(col != EMPTY, "column id collides with the empty sentinel");
        let mut i = (key as usize) & self.mask;
        loop {
            let c = self.cols[i];
            if c == EMPTY {
                break;
            }
            if self.keys[i] == key {
                return (c, false);
            }
            i = (i + 1) & self.mask;
        }
        if 2 * (self.len + 1) > self.cols.len() {
            self.grow();
            i = (key as usize) & self.mask;
            while self.cols[i] != EMPTY {
                i = (i + 1) & self.mask;
            }
        }
        self.keys[i] = key;
        self.cols[i] = col;
        self.len += 1;
        (col, true)
    }

    /// Insert (or overwrite) a bin-hash → column entry. Panics rather
    /// than hangs if the fixed-capacity table would become completely
    /// full — size it with `with_capacity(n)` for `n` distinct keys.
    pub fn insert(&mut self, key: u64, col: u32) {
        debug_assert!(col != EMPTY, "column id collides with the empty sentinel");
        let mut i = (key as usize) & self.mask;
        loop {
            if self.cols[i] == EMPTY {
                self.keys[i] = key;
                self.cols[i] = col;
                self.len += 1;
                assert!(
                    self.len < self.cols.len(),
                    "BinTable over capacity ({} entries in {} slots); \
                     build with with_capacity(n) for n distinct keys",
                    self.len,
                    self.cols.len()
                );
                return;
            }
            if self.keys[i] == key {
                self.cols[i] = col;
                return;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Column of the bin hashed to `key`, if that bin was occupied at fit
    /// time. Allocation-free.
    #[inline]
    pub fn get(&self, key: u64) -> Option<u32> {
        let mut i = (key as usize) & self.mask;
        loop {
            let c = self.cols[i];
            if c == EMPTY {
                return None;
            }
            if self.keys[i] == key {
                return Some(c);
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Number of occupied entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Iterate the occupied (bin hash, column) pairs in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, u32)> + '_ {
        self.keys
            .iter()
            .zip(self.cols.iter())
            .filter(|(_, &c)| c != EMPTY)
            .map(|(&k, &c)| (k, c))
    }
}

/// The complete, serializable description of a fitted RB feature map:
/// grid parameters plus the per-grid bin→column tables. Applying it to a
/// point yields the point's (at most R) feature columns in the training
/// matrix's column space.
#[derive(Clone, Debug)]
pub struct RbCodebook {
    /// Number of grids R.
    pub r: usize,
    /// Input dimensionality d the grids were drawn over.
    pub d_in: usize,
    /// Kernel bandwidth σ the widths were sampled for (metadata; the
    /// widths themselves are stored explicitly).
    pub sigma: f64,
    /// Seed the grids were sampled from (metadata / provenance).
    pub seed: u64,
    /// Total feature dimension D (number of occupied bins across grids).
    pub dim: usize,
    /// The R random grids (widths + biases per dimension).
    pub grids: Vec<Grid>,
    /// Per-grid bin-hash → global-column tables.
    pub tables: Vec<BinTable>,
}

impl RbCodebook {
    /// Global feature column of `row`'s bin in grid `j`, if that bin was
    /// occupied on the training set. Allocation-free.
    #[inline]
    pub fn lookup(&self, j: usize, row: &[f64]) -> Option<u32> {
        self.tables[j].get(self.grids[j].bin_hash(row))
    }

    /// Bin `row` in grid `j`, **admitting** the bin as a new global
    /// column (`self.dim`) if it was never seen before; returns
    /// `(column, admitted)`. RB's feature map is data-independent, so
    /// new data only ever grows the codebook — admitted bins extend the
    /// global column space at the end, leaving every fit-time column
    /// untouched (the incremental-update path widens the projection with
    /// matching zero rows).
    #[inline]
    pub fn admit(&mut self, j: usize, row: &[f64]) -> (u32, bool) {
        let key = self.grids[j].bin_hash(row);
        debug_assert!(self.dim < u32::MAX as usize - 1, "column space exhausted");
        let (col, admitted) = self.tables[j].get_or_insert(key, self.dim as u32);
        if admitted {
            self.dim += 1;
        }
        (col, admitted)
    }

    /// Fraction of `row`'s R bins that map to fit-time columns — a serving
    /// diagnostic: low coverage means the point is far from the training
    /// distribution and its embedding is mostly extrapolated.
    pub fn coverage(&self, row: &[f64]) -> f64 {
        let hits = (0..self.r).filter(|&j| self.lookup(j, row).is_some()).count();
        hits as f64 / self.r.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg;

    #[test]
    fn table_roundtrips_entries() {
        let mut t = BinTable::with_capacity(100);
        let mut rng = Pcg::seed(3);
        let entries: Vec<(u64, u32)> = (0..100).map(|i| (rng.next_u64(), i as u32)).collect();
        for &(k, c) in &entries {
            t.insert(k, c);
        }
        assert_eq!(t.len(), 100);
        assert!(!t.is_empty());
        for &(k, c) in &entries {
            assert_eq!(t.get(k), Some(c), "key {k:#x}");
        }
        // absent keys miss
        for _ in 0..100 {
            let k = rng.next_u64();
            if !entries.iter().any(|&(e, _)| e == k) {
                assert_eq!(t.get(k), None);
            }
        }
    }

    #[test]
    fn table_handles_clustered_keys() {
        // adversarial: keys that all collide into the same initial slot
        let mut t = BinTable::with_capacity(8);
        let base = 0x42u64;
        let cap = 16u64; // with_capacity(8) -> 16 slots
        for i in 0..8u32 {
            t.insert(base + i as u64 * cap * 4, i);
        }
        for i in 0..8u32 {
            assert_eq!(t.get(base + i as u64 * cap * 4), Some(i));
        }
        assert_eq!(t.len(), 8);
        // overwrite keeps a single entry
        t.insert(base, 99);
        assert_eq!(t.get(base), Some(99));
        assert_eq!(t.len(), 8);
    }

    #[test]
    #[should_panic(expected = "over capacity")]
    fn insert_beyond_capacity_panics_instead_of_hanging() {
        let mut t = BinTable::with_capacity(1); // 2 slots
        t.insert(1, 0);
        t.insert(2, 1); // would leave no empty slot — probes could spin
    }

    #[test]
    fn get_or_assign_is_first_seen_order_and_grows() {
        let mut t = BinTable::new();
        let mut rng = Pcg::seed(9);
        let keys: Vec<u64> = (0..500).map(|_| rng.next_u64()).collect();
        // first pass assigns dense ids in first-seen order, growing freely
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(t.get_or_assign(k), i as u32);
        }
        assert_eq!(t.len(), 500);
        // second pass (later chunks re-hitting known bins) returns the
        // same ids and changes nothing
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(t.get_or_assign(k), i as u32);
            assert_eq!(t.get(k), Some(i as u32));
        }
        assert_eq!(t.len(), 500);
    }

    #[test]
    fn get_or_assign_matches_hashmap_dictionary() {
        // the growable table must assign exactly the ids the batch path's
        // HashMap first-seen dictionary would
        use std::collections::HashMap;
        let mut t = BinTable::new();
        let mut h: HashMap<u64, u32> = HashMap::new();
        let mut rng = Pcg::seed(11);
        for _ in 0..2000 {
            let k = rng.below(300) as u64 * 0x9e37_79b9; // many repeats
            let next = h.len() as u32;
            let want = *h.entry(k).or_insert(next);
            assert_eq!(t.get_or_assign(k), want);
        }
        assert_eq!(t.len(), h.len());
    }

    #[test]
    fn get_or_insert_admits_caller_chosen_columns() {
        let mut t = BinTable::with_capacity(4);
        t.insert(10, 100);
        t.insert(20, 200);
        // known keys return their existing global column untouched
        assert_eq!(t.get_or_insert(10, 999), (100, false));
        assert_eq!(t.get_or_insert(20, 999), (200, false));
        assert_eq!(t.len(), 2);
        // unknown keys take exactly the caller's column
        assert_eq!(t.get_or_insert(30, 300), (300, true));
        assert_eq!(t.get(30), Some(300));
        assert_eq!(t.len(), 3);
        // admission grows past the original capacity without losing entries
        for i in 0..200u32 {
            let (col, ins) = t.get_or_insert(1000 + i as u64, 1000 + i);
            assert_eq!((col, ins), (1000 + i, true));
        }
        assert_eq!(t.len(), 203);
        for i in 0..200u32 {
            assert_eq!(t.get(1000 + i as u64), Some(1000 + i));
        }
        assert_eq!(t.get(10), Some(100));
    }

    #[test]
    fn codebook_admit_extends_the_global_column_space() {
        use crate::rb::grid::sample_grids;
        let grids = sample_grids(3, 2, 0.5, 7);
        let tables = vec![BinTable::new(), BinTable::new(), BinTable::new()];
        let mut cb = RbCodebook { r: 3, d_in: 2, sigma: 0.5, seed: 7, dim: 0, grids, tables };
        let a = [0.1, 0.2];
        let b = [5.0, -3.0];
        // first sight of each (grid, bin) admits a fresh global column
        let mut dim_before = cb.dim;
        for j in 0..3 {
            let (col, admitted) = cb.admit(j, &a);
            assert!(admitted);
            assert_eq!(col as usize, dim_before);
            dim_before += 1;
        }
        assert_eq!(cb.dim, 3);
        // the same point re-binned admits nothing and agrees with lookup
        for j in 0..3 {
            let (col, admitted) = cb.admit(j, &a);
            assert!(!admitted);
            assert_eq!(cb.lookup(j, &a), Some(col));
        }
        assert_eq!(cb.dim, 3);
        // a far-away point lands in distinct bins appended at the end
        for j in 0..3 {
            let (col, admitted) = cb.admit(j, &b);
            assert!(admitted, "far point must occupy new bins");
            assert!(col >= 3);
        }
        assert_eq!(cb.dim, 6);
    }

    #[test]
    fn iter_yields_all_pairs() {
        let mut t = BinTable::with_capacity(4);
        t.insert(10, 0);
        t.insert(20, 1);
        t.insert(30, 2);
        let mut got: Vec<(u64, u32)> = t.iter().collect();
        got.sort_unstable();
        assert_eq!(got, vec![(10, 0), (20, 1), (30, 2)]);
    }
}
