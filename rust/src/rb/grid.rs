//! Random grid sampling for Random Binning (Algorithm 1, lines 1–2).
//!
//! For a separable kernel k(x,y) = ∏_l k_l(|x_l − y_l|), each grid draws a
//! width ω_l from p_l(ω) ∝ ω·k_l″(ω) and a bias u_l ~ U[0, ω_l] per
//! dimension. For the Laplacian kernel k_l(δ) = exp(−δ/σ):
//! k″(ω) = e^{−ω/σ}/σ², so p(ω) = (ω/σ²)·e^{−ω/σ} — a Gamma(2, σ)
//! distribution, sampled as σ·(E₁ + E₂).

use crate::util::rng::Pcg;

/// One random grid: per-dimension widths and biases.
#[derive(Clone, Debug)]
pub struct Grid {
    /// Bin width per dimension, ω_l > 0.
    pub widths: Vec<f64>,
    /// Bin offset per dimension, u_l ∈ [0, ω_l).
    pub biases: Vec<f64>,
    /// 1/ω_l — hashing does one multiply instead of one divide per
    /// coordinate (≈19% on RB generation, EXPERIMENTS.md §Perf iter 3).
    inv_widths: Vec<f64>,
}

impl Grid {
    /// Rebuild a grid from explicit per-dimension widths and biases (the
    /// serialized form a fitted model persists — grids must be
    /// reconstructible without replaying the sampling RNG).
    pub fn from_params(widths: Vec<f64>, biases: Vec<f64>) -> Grid {
        assert_eq!(widths.len(), biases.len(), "one bias per width");
        assert!(widths.iter().all(|&w| w > 0.0), "widths must be positive");
        let inv_widths = widths.iter().map(|w| 1.0 / w).collect();
        Grid { widths, biases, inv_widths }
    }

    /// Draw a grid for the Laplacian kernel with bandwidth `sigma` over
    /// `d` dimensions.
    pub fn sample_laplacian(d: usize, sigma: f64, rng: &mut Pcg) -> Grid {
        assert!(sigma > 0.0, "sigma must be positive");
        let mut widths = Vec::with_capacity(d);
        let mut biases = Vec::with_capacity(d);
        for _ in 0..d {
            // Guard against pathologically tiny widths (numerical blowup in
            // the bin index); Gamma(2,σ) has density → 0 at 0 so this is a
            // measure-zero clamp.
            let w = rng.gamma2(sigma).max(1e-9 * sigma);
            widths.push(w);
            biases.push(rng.range_f64(0.0, w));
        }
        let inv_widths = widths.iter().map(|w| 1.0 / w).collect();
        Grid { widths, biases, inv_widths }
    }

    /// Bin coordinate of scalar `x` in dimension `l`.
    #[inline(always)]
    pub fn bin_coord(&self, l: usize, x: f64) -> i64 {
        ((x - self.biases[l]) * self.inv_widths[l]).floor() as i64
    }

    /// Hash of the full bin-index tuple of point `x` (one non-zero feature
    /// per grid — the bin this point falls in). 64-bit mixed hash over the
    /// per-dimension coordinates.
    #[inline]
    pub fn bin_hash(&self, x: &[f64]) -> u64 {
        debug_assert_eq!(x.len(), self.widths.len());
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for l in 0..x.len() {
            let c = self.bin_coord(l, x[l]) as u64;
            h ^= c.wrapping_add(0x9e37_79b9_7f4a_7c15).wrapping_add(h << 6).wrapping_add(h >> 2);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    }
}

/// Sample `r` grids deterministically from a master seed (grid j uses an
/// independent child stream, so generation parallelizes over grids).
pub fn sample_grids(r: usize, d: usize, sigma: f64, seed: u64) -> Vec<Grid> {
    let mut master = Pcg::new(seed, 0x9b1d);
    let seeds: Vec<u64> = (0..r).map(|_| master.next_u64()).collect();
    seeds
        .into_iter()
        .enumerate()
        .map(|(j, s)| {
            let mut rng = Pcg::new(s, j as u64);
            Grid::sample_laplacian(d, sigma, &mut rng)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_positive_biases_in_range() {
        let grids = sample_grids(50, 8, 2.0, 123);
        assert_eq!(grids.len(), 50);
        for g in &grids {
            assert_eq!(g.widths.len(), 8);
            for l in 0..8 {
                assert!(g.widths[l] > 0.0);
                assert!((0.0..g.widths[l]).contains(&g.biases[l]));
            }
        }
    }

    #[test]
    fn same_bin_iff_close() {
        let mut rng = Pcg::seed(5);
        let g = Grid::sample_laplacian(1, 1.0, &mut rng);
        // identical points always share a bin
        assert_eq!(g.bin_hash(&[0.3]), g.bin_hash(&[0.3]));
        // points further apart than the width never share a bin
        let far = g.widths[0] * 1.5;
        assert_ne!(g.bin_coord(0, 0.0), g.bin_coord(0, far));
    }

    #[test]
    fn collision_probability_approximates_kernel() {
        // P[same bin over all dims] = ∏ max(0, 1 − |δ_l|/ω_l) in expectation
        // ≈ k(x,y) = e^{−‖δ‖₁/σ}. Monte-Carlo over many grids.
        let sigma = 1.0;
        let x = [0.2, 0.5];
        let y = [0.5, 0.1]; // ‖δ‖₁ = 0.7
        let expect = (-0.7f64 / sigma).exp();
        let r = 60_000;
        let grids = sample_grids(r, 2, sigma, 77);
        let hits = grids
            .iter()
            .filter(|g| {
                (0..2).all(|l| g.bin_coord(l, x[l]) == g.bin_coord(l, y[l]))
            })
            .count();
        let p = hits as f64 / r as f64;
        assert!(
            (p - expect).abs() < 0.01,
            "collision prob {p:.4} vs kernel {expect:.4}"
        );
    }

    #[test]
    fn from_params_reproduces_binning() {
        let mut rng = Pcg::seed(8);
        let g = Grid::sample_laplacian(4, 1.3, &mut rng);
        let rebuilt = Grid::from_params(g.widths.clone(), g.biases.clone());
        let x = [0.7, -1.2, 3.4, 0.02];
        assert_eq!(g.bin_hash(&x), rebuilt.bin_hash(&x));
        for l in 0..4 {
            assert_eq!(g.bin_coord(l, x[l]), rebuilt.bin_coord(l, x[l]));
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = sample_grids(5, 3, 1.5, 42);
        let b = sample_grids(5, 3, 1.5, 42);
        for (ga, gb) in a.iter().zip(b.iter()) {
            assert_eq!(ga.widths, gb.widths);
            assert_eq!(ga.biases, gb.biases);
        }
    }
}
