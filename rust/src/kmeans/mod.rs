//! K-means substrate: k-means++ seeding, Lloyd iterations with replicates
//! (the paper's protocol: Matlab kmeans, 10 replicates), a mini-batch mode
//! for multi-million-point runs, and a pluggable assignment engine so the
//! XLA runtime can offload the distance computation (the `NK²t` hot spot).

use crate::linalg::{sqdist, Mat};
use crate::util::rng::Pcg;
use crate::util::threads::{num_threads, parallel_rows_mut};

/// Assignment engine: nearest centroid per row. The native engine runs
/// threaded Rust; `runtime::XlaAssign` offloads to an AOT Pallas kernel.
/// Called from the coordinator thread only (implementations parallelize
/// internally), so no `Sync` bound — the XLA engine holds a device cache.
pub trait AssignEngine {
    /// Returns (labels, squared distance to the assigned centroid).
    fn assign(&self, x: &Mat, centroids: &Mat) -> (Vec<u32>, Vec<f64>);
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Nearest centroid of `xi`: (index, squared distance). Ties go to the
/// lowest index. This is **the** argmin: the native assignment engine and
/// the serving models (`model::FittedModel::predict*`) both route through
/// it, so fit-time assignment and serve-time prediction cannot drift
/// apart behaviorally.
#[inline]
pub fn nearest_centroid(xi: &[f64], centroids: &Mat) -> (u32, f64) {
    let mut best = 0u32;
    let mut bd = f64::INFINITY;
    for c in 0..centroids.rows {
        let d = sqdist(xi, centroids.row(c));
        if d < bd {
            bd = d;
            best = c as u32;
        }
    }
    (best, bd)
}

/// Threaded pure-Rust assignment.
pub struct NativeAssign;

impl AssignEngine for NativeAssign {
    fn assign(&self, x: &Mat, centroids: &Mat) -> (Vec<u32>, Vec<f64>) {
        let n = x.rows;
        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f64; n];
        // process rows in parallel; labels+dists written via zipped panels
        let mut fused: Vec<(u32, f64)> = vec![(0, 0.0); n];
        parallel_rows_mut(&mut fused, 1, |row0, chunk| {
            for (t, slot) in chunk.iter_mut().enumerate() {
                *slot = nearest_centroid(x.row(row0 + t), centroids);
            }
        });
        for (i, (l, d)) in fused.into_iter().enumerate() {
            labels[i] = l;
            dists[i] = d;
        }
        (labels, dists)
    }
}

/// K-means options.
#[derive(Clone, Debug)]
pub struct KmeansOpts {
    pub k: usize,
    pub replicates: usize,
    pub max_iters: usize,
    /// Relative inertia improvement below which Lloyd stops.
    pub tol: f64,
    pub seed: u64,
    /// Mini-batch size; None = full-batch Lloyd.
    pub batch: Option<usize>,
}

impl KmeansOpts {
    pub fn new(k: usize) -> Self {
        KmeansOpts { k, replicates: 10, max_iters: 100, tol: 1e-6, seed: 42, batch: None }
    }
}

/// K-means output.
#[derive(Clone, Debug)]
pub struct KmeansResult {
    pub labels: Vec<u32>,
    pub centroids: Mat,
    /// Sum of squared distances to assigned centroids.
    pub inertia: f64,
    /// Total Lloyd iterations across the winning replicate.
    pub iterations: usize,
}

/// k-means++ seeding (Arthur & Vassilvitskii).
pub fn kmeanspp_init(x: &Mat, k: usize, rng: &mut Pcg) -> Mat {
    let n = x.rows;
    assert!(k >= 1 && n >= 1);
    let mut centroids = Mat::zeros(k.min(n), x.cols);
    let first = rng.below(n);
    centroids.row_mut(0).copy_from_slice(x.row(first));
    let mut d2: Vec<f64> = (0..n).map(|i| sqdist(x.row(i), centroids.row(0))).collect();
    for c in 1..k.min(n) {
        let total: f64 = d2.iter().sum();
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.f64() * total;
            let mut idx = n - 1;
            for (i, &w) in d2.iter().enumerate() {
                if target < w {
                    idx = i;
                    break;
                }
                target -= w;
            }
            idx
        };
        centroids.row_mut(c).copy_from_slice(x.row(pick));
        // update distances
        for i in 0..n {
            let d = sqdist(x.row(i), centroids.row(c));
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }
    centroids
}

/// Centroid update: mean of assigned points (parallel partial sums).
/// Returns per-cluster counts.
fn update_centroids(x: &Mat, labels: &[u32], k: usize, centroids: &mut Mat) -> Vec<usize> {
    let d = x.cols;
    let nt = num_threads();
    let chunk = x.rows.div_ceil(nt).max(1);
    let partials: Vec<(Mat, Vec<usize>)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..nt {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(x.rows);
            if lo >= hi {
                break;
            }
            handles.push(s.spawn(move || {
                let mut sums = Mat::zeros(k, d);
                let mut counts = vec![0usize; k];
                for i in lo..hi {
                    let c = labels[i] as usize;
                    counts[c] += 1;
                    let row = x.row(i);
                    let srow = sums.row_mut(c);
                    for (sv, xv) in srow.iter_mut().zip(row.iter()) {
                        *sv += *xv;
                    }
                }
                (sums, counts)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut sums = Mat::zeros(k, d);
    let mut counts = vec![0usize; k];
    for (ps, pc) in partials {
        sums.add_assign(&ps);
        for (c, p) in counts.iter_mut().zip(pc.iter()) {
            *c += *p;
        }
    }
    for c in 0..k {
        if counts[c] > 0 {
            let inv = 1.0 / counts[c] as f64;
            let srow = sums.row(c).to_vec();
            for (cv, sv) in centroids.row_mut(c).iter_mut().zip(srow.iter()) {
                *cv = sv * inv;
            }
        }
    }
    counts
}

/// One full-batch Lloyd run from a given init.
fn lloyd(
    x: &Mat,
    mut centroids: Mat,
    opts: &KmeansOpts,
    engine: &dyn AssignEngine,
    rng: &mut Pcg,
) -> KmeansResult {
    let k = centroids.rows;
    let mut prev_inertia = f64::INFINITY;
    let mut labels = vec![0u32; x.rows];
    let mut iterations = 0;
    for _it in 0..opts.max_iters {
        iterations += 1;
        let (lab, dists) = engine.assign(x, &centroids);
        labels = lab;
        let inertia: f64 = dists.iter().sum();
        let counts = update_centroids(x, &labels, k, &mut centroids);
        // reseed empty clusters at the farthest points
        for c in 0..k {
            if counts[c] == 0 {
                let far = dists
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or_else(|| rng.below(x.rows));
                centroids.row_mut(c).copy_from_slice(x.row(far));
            }
        }
        if prev_inertia.is_finite() && (prev_inertia - inertia) <= opts.tol * prev_inertia.abs() {
            break;
        }
        prev_inertia = inertia;
    }
    // final consistent assignment
    let (lab, dists) = engine.assign(x, &centroids);
    labels = lab;
    let inertia = dists.iter().sum();
    KmeansResult { labels, centroids, inertia, iterations }
}

/// Mini-batch K-means (Sculley 2010): per-batch assignment and running
/// per-centroid learning rates. Used for the 4M-point SUSY-like run.
fn minibatch(
    x: &Mat,
    mut centroids: Mat,
    batch: usize,
    opts: &KmeansOpts,
    engine: &dyn AssignEngine,
    rng: &mut Pcg,
) -> KmeansResult {
    let n = x.rows;
    let k = centroids.rows;
    let mut counts = vec![1usize; k];
    let iters = opts.max_iters.max(10);
    for _ in 0..iters {
        let idx = rng.sample_indices(n, batch.min(n));
        let xb = x.select_rows(&idx);
        let (labels, _) = engine.assign(&xb, &centroids);
        for (row, &c) in labels.iter().enumerate() {
            let c = c as usize;
            counts[c] += 1;
            let eta = 1.0 / counts[c] as f64;
            let xrow = xb.row(row).to_vec();
            for (cv, xv) in centroids.row_mut(c).iter_mut().zip(xrow.iter()) {
                *cv += eta * (xv - *cv);
            }
        }
    }
    let (labels, dists) = engine.assign(x, &centroids);
    let inertia = dists.iter().sum();
    KmeansResult { labels, centroids, inertia, iterations: iters }
}

/// Run K-means with replicates, keeping the lowest-inertia solution.
pub fn kmeans(x: &Mat, opts: &KmeansOpts, engine: &dyn AssignEngine) -> KmeansResult {
    assert!(x.rows > 0, "empty data");
    let k = opts.k.min(x.rows);
    let mut best: Option<KmeansResult> = None;
    for rep in 0..opts.replicates.max(1) {
        let mut rng = Pcg::new(opts.seed, kmeans_stream(rep));
        let init = kmeanspp_init(x, k, &mut rng);
        let result = match opts.batch {
            Some(b) if b < x.rows => minibatch(x, init, b, opts, engine, &mut rng),
            _ => lloyd(x, init, opts, engine, &mut rng),
        };
        let better = best.as_ref().map(|b| result.inertia < b.inertia).unwrap_or(true);
        if better {
            best = Some(result);
        }
    }
    best.unwrap()
}

/// Per-replicate RNG stream id.
#[inline]
fn kmeans_stream(rep: usize) -> u64 {
    0x6b6d_0000u64 + rep as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn three_blobs(per: usize, seed: u64) -> (Mat, Vec<u32>) {
        let centers = [[0.0, 0.0], [6.0, 0.0], [0.0, 6.0]];
        let mut rng = Pcg::seed(seed);
        let n = per * 3;
        let mut x = Mat::zeros(n, 2);
        let mut y = vec![0u32; n];
        for c in 0..3 {
            for i in 0..per {
                let row = c * per + i;
                x.set(row, 0, centers[c][0] + 0.5 * rng.normal());
                x.set(row, 1, centers[c][1] + 0.5 * rng.normal());
                y[row] = c as u32;
            }
        }
        (x, y)
    }

    #[test]
    fn recovers_separated_blobs() {
        let (x, y) = three_blobs(100, 7);
        let mut opts = KmeansOpts::new(3);
        opts.replicates = 5;
        let r = kmeans(&x, &opts, &NativeAssign);
        // same-cluster pairs agree (label permutation invariant)
        let mut agree = 0usize;
        let mut total = 0usize;
        for i in 0..300 {
            for j in 0..i {
                total += 1;
                if (y[i] == y[j]) == (r.labels[i] == r.labels[j]) {
                    agree += 1;
                }
            }
        }
        assert!(agree as f64 / total as f64 > 0.99, "pair agreement {}", agree as f64 / total as f64);
    }

    #[test]
    fn inertia_decreases_with_k() {
        let (x, _) = three_blobs(60, 9);
        let mut inertias = Vec::new();
        for k in [1usize, 2, 3, 6] {
            let mut opts = KmeansOpts::new(k);
            opts.replicates = 3;
            inertias.push(kmeans(&x, &opts, &NativeAssign).inertia);
        }
        for w in inertias.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "inertia must not increase with k: {inertias:?}");
        }
    }

    #[test]
    fn k_ge_n_degenerates_cleanly() {
        let x = Mat::from_vec(3, 1, vec![0.0, 1.0, 2.0]);
        let mut opts = KmeansOpts::new(10);
        opts.replicates = 1;
        let r = kmeans(&x, &opts, &NativeAssign);
        assert!(r.inertia < 1e-12);
    }

    #[test]
    fn minibatch_close_to_full() {
        let (x, _) = three_blobs(200, 11);
        let mut full = KmeansOpts::new(3);
        full.replicates = 3;
        let rf = kmeans(&x, &full, &NativeAssign);
        let mut mb = KmeansOpts::new(3);
        mb.replicates = 3;
        mb.batch = Some(100);
        mb.max_iters = 60;
        let rm = kmeans(&x, &mb, &NativeAssign);
        assert!(rm.inertia < rf.inertia * 1.5, "minibatch {} vs full {}", rm.inertia, rf.inertia);
    }

    #[test]
    fn deterministic_in_seed() {
        let (x, _) = three_blobs(50, 13);
        let opts = KmeansOpts { replicates: 2, ..KmeansOpts::new(3) };
        let a = kmeans(&x, &opts, &NativeAssign);
        let b = kmeans(&x, &opts, &NativeAssign);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.inertia, b.inertia);
    }
}
