//! # scrb — Scalable Spectral Clustering using Random Binning features
//!
//! A production-shaped reproduction of *"Scalable Spectral Clustering Using
//! Random Binning Features"* (Wu et al., KDD 2018).
//!
//! The crate is the Layer-3 Rust coordinator of a three-layer stack:
//! - **L3 (this crate)**: the full clustering framework — RB feature
//!   generation, implicit-Laplacian sparse algebra, PRIMME-style iterative
//!   SVD, K-means, eight baseline methods, metrics, datasets, the
//!   experiment coordinator that regenerates every table and figure of the
//!   paper, the [`model`] layer (fit / transform / predict with model
//!   persistence), and the [`pipeline`] layer that expresses every method
//!   as typed, cacheable stages.
//! - **L2 (python/compile/model.py)**: JAX compute graphs for the dense hot
//!   spots (K-means assignment, exact kernel blocks, RF feature maps).
//! - **L1 (python/compile/kernels/)**: Pallas kernels implementing those
//!   graphs, AOT-lowered to HLO text artifacts loaded by [`runtime`].
//!
//! Python never runs on the request path: `scrb` is self-contained once
//! `artifacts/` is built, and every XLA path has a native fallback.
//!
//! ## The staged pipeline
//!
//! Algorithm 2 is a staged computation — featurize, embed, cluster — and
//! every method in the paper's comparison grid is a swap of exactly those
//! stages. The [`pipeline`] module makes that the API: typed stage traits
//! (`Normalize` → `Featurize` → `Embed` → `Cluster`) joined by a
//! [`pipeline::Pipeline`] driver, where each stage emits a fingerprinted,
//! cacheable artifact. [`cluster::MethodKind::pipeline`] is the
//! composition table for all nine methods, and an
//! [`pipeline::ArtifactCache`] lets sweeps re-run only the stages a
//! config change invalidated:
//!
//! ```no_run
//! use scrb::cluster::{Env, MethodKind};
//! use scrb::config::PipelineConfig;
//! use scrb::data::synth;
//! use scrb::pipeline::ArtifactCache;
//!
//! let ds = synth::two_moons(2000, 0.06, 7);
//! let cfg = PipelineConfig::builder().k(2).r(128).sigma(0.15).build();
//! let mut cache = ArtifactCache::new();
//! // k-sweep with a pinned embedding width: RB featurization and the
//! // SVD embedding run once; only K-means re-runs per grid point
//! for k in [2usize, 3, 4] {
//!     let cfg_k = cfg.rebuild(|b| b.embed_dim(4).k(k)).unwrap();
//!     let env = Env::new(cfg_k.clone());
//!     let fitted = MethodKind::ScRb
//!         .pipeline(&cfg_k)
//!         .fit_cached(&env, &ds.x, &mut cache)
//!         .unwrap();
//!     // the embedding artifact (Σ, U, the serving projection) is a
//!     // first-class value — export it standalone, no refit
//!     println!("k={k}: σ₁={:.4}", fitted.embedding.s[0]);
//! }
//! ```
//!
//! ## Sparse substrates
//!
//! Two sparse layouts back the implicit-Laplacian algebra:
//! - [`sparse::EllRb`] — fixed-stride RB substrate: flat n×R u32 indices,
//!   one f64 scale per row (the `D^{-1/2}/√R` weight), and a precomputed
//!   column-strip transpose layout. This is what [`rb::rb_features`] emits
//!   and what every `Ẑ·B` / `Ẑᵀ·B` in the eigensolver hot path runs on —
//!   transpose products write disjoint output strips per thread with no
//!   per-thread D×k accumulators and no reduction.
//! - [`sparse::Csr`] — general CSR for baselines and irregular sparsity;
//!   [`sparse::EllRb::to_csr`] bridges between them, and property tests
//!   pin the two substrates to agree on every solver-visible operation.
//!
//! ## Solver selection
//!
//! Three spectral backends sit behind `--solver` / the config's
//! [`config::Solver`], all driving the same fused gram kernel:
//!
//! - **`davidson`** (default) — block Generalized Davidson with thick
//!   restart and diagonal preconditioning. Fastest to tight tolerances;
//!   the reference the paper's tables use.
//! - **`lanczos`** — restarted Golub–Kahan bidiagonalization, the Matlab
//!   `svds` analogue. Simpler per-iteration work, more iterations.
//! - **`compressive`** — Compressive Spectral Clustering: an order-p
//!   Chebyshev approximation of the ideal low-pass filter applied to
//!   O(log n) random signals, k-means on a sampled row subset, and
//!   Tikhonov label interpolation back to all rows. No per-iteration
//!   orthogonalization at all — the whole solve is p fused gram block
//!   products, so its cost is *fixed up front* and indifferent to
//!   spectral gaps that stall the eigensolvers.
//!
//! The compressive backend trades along three axes ([`config::PipelineConfig`]
//! knobs): `cheb_order` (sharper spectral cut ↔ linearly more gram
//! products), `cheb_signals` (embedding fidelity ↔ wider blocks), and
//! `cheb_sample` (k-means cost ↔ label-interpolation quality). Prefer it
//! over `lanczos` when K is large (eigensolver orthogonalization costs
//! grow with the basis; the filter never orthogonalizes), when the
//! spectrum near λ_K is clustered (restarted Lanczos stalls, the filter
//! does not care), or when a fixed compute budget matters more than a
//! certified tolerance. Prefer the eigensolvers when K is small and
//! tight Ritz accuracy is the point. `cargo bench --bench bench_solvers`
//! sweeps all three (plus the compressive order axis) and reports
//! time-to-embedding and end-to-end NMI side by side.
//!
//! ## Quickstart
//!
//! ```no_run
//! use scrb::cluster::ScRb;
//! use scrb::config::PipelineConfig;
//! use scrb::data::synth;
//!
//! let ds = synth::two_moons(2000, 0.06, 7);
//! let cfg = PipelineConfig::builder().k(2).r(128).build();
//! let out = ScRb::new(cfg).run(&ds.x).expect("clustering failed");
//! println!("labels: {:?}", &out.labels[..10]);
//! ```
//!
//! ## Fit once, predict many (serving)
//!
//! ```no_run
//! use scrb::cluster::ScRb;
//! use scrb::config::PipelineConfig;
//! use scrb::data::synth;
//! use scrb::model::{FittedModel, ScRbModel, ServeWorkspace};
//!
//! let train = synth::two_moons(2000, 0.06, 7);
//! let cfg = PipelineConfig::builder().k(2).r(128).build();
//! let fitted = ScRb::new(cfg).fit(&train.x).expect("fit failed");
//! fitted.model.save("moons.scrb").expect("save failed");
//!
//! // later / elsewhere: load and serve — no solver, no refit
//! let model = ScRbModel::load("moons.scrb").expect("load failed");
//! let fresh = synth::two_moons(100, 0.06, 99);
//! let mut ws = ServeWorkspace::new();
//! let mut labels = Vec::new();
//! model.predict_batch(&fresh.x, &mut ws, &mut labels).expect("predict failed");
//!
//! // keep the model current as new data arrives — no refit unless drift
//! // says so (see "Model lifecycle" below)
//! # use scrb::update::{UpdateConfig, UpdateWorkspace};
//! # use scrb::stream::SparseChunk;
//! # let mut model = model; let chunk = SparseChunk::new();
//! let mut uws = UpdateWorkspace::new();
//! let report = model.update(&chunk, &UpdateConfig::default(), &mut uws).expect("update failed");
//! println!("absorbed {} rows, admitted {} new bins", report.rows, report.admitted);
//! ```
//!
//! ## Model lifecycle: fit → serve → update → refit
//!
//! A model is not a one-shot artifact; [`update`] keeps it live as the
//! data moves:
//!
//! 1. **fit** — `scrb fit --save m.scrb` (in-memory or `--stream`).
//! 2. **serve** — `scrb serve --model m.scrb`: predictions, drift
//!    counters, hot swap.
//! 3. **update** — `scrb update --model m.scrb --data new.libsvm --save
//!    m2.scrb`: incremental maintenance at a fraction of refit cost.
//!    Unseen bins are *admitted* as new codebook columns (fit-time
//!    columns never move), the spectral subspace absorbs the new rows by
//!    a rank-k incremental SVD, and the k-means centroids are polished
//!    from the previous solution — no reseeding. Steady-state updates
//!    allocate nothing; in-distribution chunks change nothing but the
//!    persisted counters (SCRBMODL v3 trailer, [`model::UpdateState`]).
//! 4. **refit** — each update folds its pre-admission unseen-bin rate
//!    and subspace residual into persisted EWMAs
//!    ([`update::DriftTracker`]); past the configured thresholds the
//!    update returns [`update::UpdateOutcome::RefitNeeded`] and the
//!    incremental path *escalates*: `scrb update --refit` runs the full
//!    streamed refit with the model's frozen parameters and can publish
//!    it to a running daemon through the validated hot-swap slot
//!    (`--swap ADDR`). The trigger is deterministic under a fixed seed.
//!
//! ```no_run
//! use scrb::model::ScRbModel;
//! use scrb::stream::{IngestPolicy, LibsvmChunks};
//! use scrb::update::{update_streaming, UpdateConfig, UpdateWorkspace};
//!
//! let mut model = ScRbModel::load("m.scrb").expect("load failed");
//! let mut reader = LibsvmChunks::from_path("new.libsvm", 4096).expect("open failed");
//! let mut ws = UpdateWorkspace::new();
//! let out = update_streaming(
//!     &mut model, &mut reader, &UpdateConfig::default(), IngestPolicy::default(), &mut ws,
//! ).expect("update failed");
//! if out.refit_needed {
//!     eprintln!("drift thresholds crossed after {} rows: run `scrb update --refit`", out.rows);
//! }
//! model.save("m2.scrb").expect("save failed");
//! ```
//!
//! ## Clustering as a service
//!
//! `scrb serve --model m.scrb --addr 127.0.0.1:7878` turns a saved model
//! into a long-lived daemon ([`serve`]): a checksummed binary protocol
//! over TCP, micro-batched `predict_batch` workers, bounded admission
//! with explicit load shedding, per-request deadlines, and atomic hot
//! model swap with validate-before-publish and rollback. [`serve::ServeClient`]
//! is the matching blocking client:
//!
//! ```no_run
//! use scrb::linalg::Mat;
//! use scrb::serve::ServeClient;
//!
//! let mut c = ServeClient::connect("127.0.0.1:7878").expect("connect");
//! let (version, labels) = c.predict(&Mat::from_vec(1, 3, vec![0.2, 0.5, 0.8])).expect("predict");
//! println!("model v{version} says {labels:?}");
//! let new_version = c.swap("refit.scrb").expect("swap validated and published");
//! # let _ = new_version;
//! ```
//!
//! ## Out-of-core fit (streaming)
//!
//! Datasets too big to densify fit through the [`stream`] subsystem: the
//! same SC_RB stage composition, with the featurize stage fed by a
//! chunked [`stream::ChunkReader`] (two bounded-memory passes into the
//! [`sparse::BlockEllRb`] substrate) instead of an in-memory matrix. The
//! embed → cluster → assemble tail is the *identical* driver code the
//! in-memory fit runs, so the streamed model is **byte-identical** to the
//! in-memory fit's on the same data and seed:
//!
//! ```no_run
//! use scrb::cluster::Env;
//! use scrb::config::PipelineConfig;
//! use scrb::model::FittedModel;
//! use scrb::stream::{fit_streaming, LibsvmChunks, StreamOpts};
//!
//! let cfg = PipelineConfig::builder().r(256).sigma(0.25).build();
//! let mut reader = LibsvmChunks::from_path("big.libsvm", 4096).expect("open failed");
//! let fitted = fit_streaming(&Env::new(cfg), &mut reader, &StreamOpts::default())
//!     .expect("streaming fit failed");
//! fitted.model.save("big.scrb").expect("save failed");
//! ```
//!
//! When one scan thread can't keep the pipeline fed, the [`shard`]
//! subsystem parallelizes the featurization across K shards — byte-range
//! windows of one file, or whole-file runs over a multi-file/glob
//! dataset — and merges the shard-local codebooks back into the
//! canonical first-seen order. The merged fit stays **byte-identical**
//! to the sequential one, for any shard count (`scrb fit --stream
//! --shards K` at the CLI, [`stream::fit_streaming_sharded`] in code):
//!
//! ```no_run
//! use scrb::cluster::Env;
//! use scrb::config::PipelineConfig;
//! use scrb::shard::{ShardFormat, ShardPlanner};
//! use scrb::stream::{fit_streaming_sharded, ChunkReader, StreamOpts};
//!
//! let cfg = PipelineConfig::builder().r(256).sigma(0.25).build();
//! let plan = ShardPlanner::new(8, 4096, ShardFormat::Libsvm)
//!     .plan(&["parts/*.libsvm".to_string()])
//!     .expect("plan failed");
//! let mut readers = ShardPlanner::open(&plan).expect("open failed");
//! let mut refs: Vec<&mut (dyn ChunkReader + Send)> =
//!     readers.iter_mut().map(|r| r.as_mut()).collect();
//! let fitted = fit_streaming_sharded(&Env::new(cfg), &mut refs, &StreamOpts::default())
//!     .expect("sharded fit failed");
//! fitted.model.save("big.scrb").expect("save failed");
//! ```
//!
//! ## Failure modes & recovery
//!
//! Streamed fits run against real files on real infrastructure, so every
//! failure class has a defined treatment (all verified under seeded fault
//! injection in `tests/faults.rs`):
//!
//! - **Malformed / non-finite records** — strict mode (default) fails the
//!   fit on the first offender with a located [`error::ScrbError::BadRecord`]
//!   (file, 1-based line, byte offset, quoted token); quarantine mode
//!   (`--on-bad-record quarantine`, [`stream::OnBadRecord`]) skips the
//!   row deterministically in *both* passes, keeps exact counts, and
//!   samples offenders into [`stream::Quarantine`]. A quarantined fit is
//!   byte-identical to a fit on the clean subset of the data.
//! - **Transient I/O errors** — retried with bounded exponential backoff
//!   ([`stream::IngestPolicy::max_retries`]); absorbed retries never
//!   change a model byte, exhausted retries surface as
//!   [`error::ScrbError::Transient`] with the attempt count.
//! - **Process death mid-fit** — with `--checkpoint DIR`
//!   ([`stream::CheckpointCfg`]) the fit persists its pass-1 stats and
//!   incremental pass-2 state (atomic tmp-rename writes, checksum
//!   footers); rerunning with `--resume` continues to the
//!   **byte-identical** model an uninterrupted fit would have produced.
//!   Incompatible parameters or torn files are typed
//!   [`error::ScrbError::Checkpoint`] errors, never silently-wrong models.
//! - **Model file corruption** — `.scrb` images end with an FNV-1a
//!   checksum footer (format v2); any truncation or byte flip is a typed
//!   [`error::ScrbError::Model`] at load, and v1 files still load.
//! - **Serving drift** — every `transform`/`predict` counts bin lookups
//!   that miss the fit-time codebook ([`model::ScRbModel::drift_stats`])
//!   and warns when a call's unseen rate crosses
//!   [`model::ScRbModel::unseen_warn`] (`--unseen-warn` at the CLI).
//!   Warnings are rate-limited (at most one per [`model::WARN_EVERY`]
//!   offending calls, with cumulative counts in the message) so sustained
//!   drift cannot flood a daemon's stderr; the exact offender and warning
//!   counts stay in [`model::DriftStats`].
//!
//! The serving daemon ([`serve`]) extends the same discipline to the
//! request path (verified under seeded fault injection in
//! `tests/serve.rs`):
//!
//! - **Overload** — a full admission queue sheds the request with a typed
//!   [`serve::ErrorCode::Overloaded`] rejection (counted in `STATUS`);
//!   nothing blocks, nothing is silently dropped.
//! - **Missed deadlines** — a request a worker reaches after its deadline
//!   is answered [`serve::ErrorCode::Timeout`] instead of served stale.
//! - **Broken frames** — malformed, truncated, or oversized frames get
//!   typed protocol errors, not dropped connections; only a destroyed
//!   frame boundary (bad header) closes the connection.
//! - **Worker panics** — contained per batch: the worker restarts with
//!   fresh scratch, the poisoned batch is answered
//!   [`serve::ErrorCode::Internal`], all other in-flight requests are
//!   unaffected.
//! - **Bad model swaps** — a swap candidate must pass the checksummed
//!   loader and a self-check prediction before being atomically
//!   published; failures roll back to the serving model and are recorded
//!   in the swap history. Workers pin the model per batch, so in-flight
//!   requests never straddle a swap.
//! - **Shutdown** — SIGTERM or a `Drain` frame stops admission, answers
//!   every queued request, then exits.
//!
//! ```no_run
//! use scrb::cluster::Env;
//! use scrb::config::PipelineConfig;
//! use scrb::stream::{
//!     fit_streaming, CheckpointCfg, IngestPolicy, LibsvmChunks, OnBadRecord, StreamOpts,
//! };
//!
//! let cfg = PipelineConfig::builder().r(256).sigma(0.25).build();
//! let opts = StreamOpts {
//!     policy: IngestPolicy { on_bad_record: OnBadRecord::Quarantine, ..IngestPolicy::default() },
//!     checkpoint: Some(CheckpointCfg { resume: true, ..CheckpointCfg::new("big.ckpt") }),
//!     ..StreamOpts::default()
//! };
//! let mut reader = LibsvmChunks::from_path("big.libsvm", 4096).expect("open failed");
//! let fitted = fit_streaming(&Env::new(cfg), &mut reader, &opts).expect("fit failed");
//! eprintln!("{}", fitted.quarantine.summary());
//! ```

// CI runs `cargo clippy --release -- -D warnings`. These idiom lints are
// deliberately allowed: the numeric kernels use explicit-index loops where
// the index IS the math (row/column/bin ids), and constructors with
// domain-named zero-arg builders keep call sites self-documenting.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_memcpy,
    clippy::type_complexity
)]

pub mod cli;
pub mod config;
pub mod error;
pub mod linalg;
pub mod sparse;
pub mod util;

// modules below are enabled as they land (scaffolding order)
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod eigen;
pub mod kernels;
pub mod kmeans;
pub mod metrics;
pub mod model;
pub mod pipeline;
pub mod rb;
pub mod rf;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod stream;
pub mod update;

/// Crate version string.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
