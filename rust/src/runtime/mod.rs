//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Pattern (see /opt/xla-example/load_hlo): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation` → `client.compile`
//! → `execute`. Compiled executables are cached per artifact. All
//! artifacts compute in f32; the coordinator's f64 data is converted at
//! this boundary.
//!
//! Padding contract (matches the Pallas kernels' zero-padded tiles):
//! - extra feature dimensions are zero-padded on both operands (distances
//!   and inner products are unchanged);
//! - padded centroid rows are filled with a large sentinel so they can
//!   never win an argmin;
//! - padded data rows produce garbage outputs that the caller slices off.

pub mod manifest;

pub use manifest::{ArtifactEntry, ArtifactKind, Manifest};

use crate::kmeans::AssignEngine;
use crate::linalg::Mat;
use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;

/// Sentinel coordinate for padded centroid rows.
const PAD_CENTROID: f32 = 1.0e15;

/// The XLA/PJRT execution engine.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: String,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl XlaRuntime {
    /// Load the manifest and create a CPU PJRT client.
    pub fn load(artifacts_dir: &str) -> Result<XlaRuntime> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaRuntime {
            client,
            dir: artifacts_dir.to_string(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) an artifact's executable, then run it.
    fn execute(&self, entry: &ArtifactEntry, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        {
            let cache = self.cache.borrow();
            if let Some(exe) = cache.get(&entry.name) {
                return run_exe(exe, inputs);
            }
        }
        let path = format!("{}/{}", self.dir, entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading HLO text '{path}'"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling '{path}'"))?;
        let out = run_exe(&exe, inputs);
        self.cache.borrow_mut().insert(entry.name.clone(), exe);
        out
    }

    /// K-means assignment distances via the AOT kernel. Returns
    /// (labels, squared-distances) or None when no artifact variant fits.
    pub fn kmeans_assign(&self, x: &Mat, centroids: &Mat) -> Option<(Vec<u32>, Vec<f64>)> {
        let entry =
            self.manifest.select(ArtifactKind::KmeansAssign, x.cols, centroids.rows, 0)?.clone();
        let (n, k) = (x.rows, centroids.rows);
        let (t, dp, kp) = (entry.tile, entry.dim, entry.kp);

        // centroid literal: kp×dp, padded rows pushed far away
        let mut cbuf = vec![0f32; kp * dp];
        for c in 0..kp {
            for j in 0..dp {
                cbuf[c * dp + j] = if c < k {
                    if j < centroids.cols {
                        centroids.at(c, j) as f32
                    } else {
                        0.0
                    }
                } else {
                    PAD_CENTROID
                };
            }
        }
        let clit = xla::Literal::vec1(&cbuf).reshape(&[kp as i64, dp as i64]).ok()?;

        let mut labels = vec![0u32; n];
        let mut dists = vec![0.0f64; n];
        let mut xbuf = vec![0f32; t * dp];
        let mut tile_start = 0usize;
        while tile_start < n {
            let rows = t.min(n - tile_start);
            xbuf.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..rows {
                let row = x.row(tile_start + r);
                for (j, &v) in row.iter().enumerate() {
                    xbuf[r * dp + j] = v as f32;
                }
            }
            let xlit = xla::Literal::vec1(&xbuf).reshape(&[t as i64, dp as i64]).ok()?;
            let out = self.execute(&entry, &[xlit, clit.clone()]).ok()?;
            debug_assert_eq!(out.len(), t * kp);
            for r in 0..rows {
                let row = &out[r * kp..r * kp + k];
                let (mut best, mut bd) = (0u32, f32::INFINITY);
                for (c, &d) in row.iter().enumerate() {
                    if d < bd {
                        bd = d;
                        best = c as u32;
                    }
                }
                labels[tile_start + r] = best;
                // f32 subtraction can go slightly negative
                dists[tile_start + r] = bd.max(0.0) as f64;
            }
            tile_start += rows;
        }
        Some((labels, dists))
    }

    /// Exact kernel block K(x, y) via the AOT kernel; `gamma` is 1/σ for
    /// Laplacian and 1/(2σ²) for Gaussian. Returns None if no variant fits.
    pub fn kernel_block(
        &self,
        kind: ArtifactKind,
        x: &Mat,
        y: &Mat,
        gamma: f64,
    ) -> Option<Mat> {
        assert!(matches!(
            kind,
            ArtifactKind::KernelBlockLaplacian | ArtifactKind::KernelBlockGaussian
        ));
        let entry = self.manifest.select(kind, x.cols.max(y.cols), 0, 0)?.clone();
        let (t, dp) = (entry.tile, entry.dim);
        let glit = xla::Literal::vec1(&[gamma as f32]).reshape(&[1]).ok()?;
        let mut out = Mat::zeros(x.rows, y.rows);

        let pack = |m: &Mat, start: usize, rows: usize, buf: &mut [f32]| {
            buf.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..rows {
                let row = m.row(start + r);
                for (j, &v) in row.iter().enumerate() {
                    buf[r * dp + j] = v as f32;
                }
            }
        };

        let mut xbuf = vec![0f32; t * dp];
        let mut ybuf = vec![0f32; t * dp];
        let mut xi = 0usize;
        while xi < x.rows {
            let xr = t.min(x.rows - xi);
            pack(x, xi, xr, &mut xbuf);
            let xlit = xla::Literal::vec1(&xbuf).reshape(&[t as i64, dp as i64]).ok()?;
            let mut yi = 0usize;
            while yi < y.rows {
                let yr = t.min(y.rows - yi);
                pack(y, yi, yr, &mut ybuf);
                let ylit = xla::Literal::vec1(&ybuf).reshape(&[t as i64, dp as i64]).ok()?;
                let block = self.execute(&entry, &[xlit.clone(), ylit, glit.clone()]).ok()?;
                for r in 0..xr {
                    for c in 0..yr {
                        out.set(xi + r, yi + c, block[r * t + c] as f64);
                    }
                }
                yi += yr;
            }
            xi += xr;
        }
        Some(out)
    }

    /// RF feature map cos(x·W + b) via the AOT kernel (caller applies the
    /// √(2/R) scale and slices to the true R). Returns None if no fit.
    pub fn rf_features(&self, x: &Mat, w: &Mat, b: &[f64]) -> Option<Mat> {
        let r_actual = b.len();
        let entry = self.manifest.select(ArtifactKind::RfFeatures, x.cols, 0, r_actual)?.clone();
        let (t, dp, rp) = (entry.tile, entry.dim, entry.r);

        // W (d×r) padded to dp×rp, b to rp
        let mut wbuf = vec![0f32; dp * rp];
        for i in 0..w.rows {
            for j in 0..w.cols {
                wbuf[i * rp + j] = w.at(i, j) as f32;
            }
        }
        let wlit = xla::Literal::vec1(&wbuf).reshape(&[dp as i64, rp as i64]).ok()?;
        let mut bbuf = vec![0f32; rp];
        for (j, &v) in b.iter().enumerate() {
            bbuf[j] = v as f32;
        }
        let blit = xla::Literal::vec1(&bbuf).reshape(&[rp as i64]).ok()?;

        let mut out = Mat::zeros(x.rows, r_actual);
        let mut xbuf = vec![0f32; t * dp];
        let mut xi = 0usize;
        while xi < x.rows {
            let rows = t.min(x.rows - xi);
            xbuf.iter_mut().for_each(|v| *v = 0.0);
            for r in 0..rows {
                let row = x.row(xi + r);
                for (j, &v) in row.iter().enumerate() {
                    xbuf[r * dp + j] = v as f32;
                }
            }
            let xlit = xla::Literal::vec1(&xbuf).reshape(&[t as i64, dp as i64]).ok()?;
            let z = self.execute(&entry, &[xlit, wlit.clone(), blit.clone()]).ok()?;
            for r in 0..rows {
                for j in 0..r_actual {
                    out.set(xi + r, j, z[r * rp + j] as f64);
                }
            }
            xi += rows;
        }
        Some(out)
    }
}

fn run_exe(exe: &xla::PjRtLoadedExecutable, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
    let result = exe.execute::<xla::Literal>(inputs).context("executing artifact")?;
    let lit = result[0][0].to_literal_sync().context("fetching result")?;
    // aot.py lowers with return_tuple=True → 1-tuple
    let out = lit.to_tuple1().context("unwrapping result tuple")?;
    out.to_vec::<f32>().context("converting result to f32 vec")
}

// ------------------------------------------------------------------
// Engine-selection heuristics (§Perf pass, calibrated on this box).
//
// The AOT artifacts compute on zero-padded tiles, so a request pays for
// `tiles·T·Dp·Kp` multiply-adds while the native path pays `n·d·k`. The
// f32 XLA gemm is ~4-8× faster per (padded) flop than the threaded f64
// native loops, but per-execute dispatch costs ~0.5-1 ms — measured
// break-evens on the CPU PJRT backend:
//   kmeans assign:   padded ≤ 2× native work and n large enough
//   rf features:     padded ≤ 5× native work
//   kernel block:    Gaussian always wins (matmul form on the MXU path);
//                    Laplacian only when padding is slim (Dp ≤ 1.5·d) or
//                    the dims are tiny.

impl XlaRuntime {
    /// Would the XLA kmeans-assign artifact beat the native engine here?
    pub fn assign_worthwhile(&self, n: usize, d: usize, k: usize) -> bool {
        match self.manifest.select(ArtifactKind::KmeansAssign, d, k, 0) {
            Some(e) => {
                let padded = n.div_ceil(e.tile) * e.tile * e.dim * e.kp;
                let native = n * d * k;
                padded <= 2 * native && native >= 2_000_000
            }
            None => false,
        }
    }

    /// Would the XLA rf-features artifact beat the native map here?
    pub fn rf_worthwhile(&self, n: usize, d: usize, r: usize) -> bool {
        match self.manifest.select(ArtifactKind::RfFeatures, d, 0, r) {
            Some(e) => {
                let padded = n.div_ceil(e.tile) * e.tile * e.dim * e.r;
                let native = n * d * r;
                padded <= 5 * native
            }
            None => false,
        }
    }

    /// Would the XLA kernel-block artifact beat the native loop here?
    pub fn kernel_block_worthwhile(&self, kind: ArtifactKind, d: usize) -> bool {
        match self.manifest.select(kind, d, 0, 0) {
            Some(e) => match kind {
                // matmul form: the XLA path wins at every measured size
                ArtifactKind::KernelBlockGaussian => true,
                // L1-distance form: only with slim padding or tiny dims
                ArtifactKind::KernelBlockLaplacian => e.dim <= (3 * d) / 2 || d <= 32,
                _ => false,
            },
            None => false,
        }
    }
}

/// [`AssignEngine`] backed by the XLA runtime. Falls back to the native
/// engine when no artifact variant fits or when padding overhead would
/// make the artifact slower (see the calibrated heuristics above).
pub struct XlaAssign<'a> {
    pub runtime: &'a XlaRuntime,
    /// Skip the cost model and always use the artifact (--engine xla).
    pub force: bool,
}

impl<'a> XlaAssign<'a> {
    pub fn new(runtime: &'a XlaRuntime) -> Self {
        XlaAssign { runtime, force: false }
    }
}

impl<'a> AssignEngine for XlaAssign<'a> {
    fn assign(&self, x: &Mat, centroids: &Mat) -> (Vec<u32>, Vec<f64>) {
        let worthwhile =
            self.force || self.runtime.assign_worthwhile(x.rows, x.cols, centroids.rows);
        if worthwhile {
            if let Some(r) = self.runtime.kmeans_assign(x, centroids) {
                return r;
            }
        }
        crate::kmeans::NativeAssign.assign(x, centroids)
    }
    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    // Runtime behaviour against real artifacts is covered by
    // rust/tests/runtime_xla.rs (needs `make artifacts` first). Manifest
    // parsing/selection is tested in `manifest`.
}
