//! Artifact manifest: `python/compile/aot.py` lowers each L2 graph for a
//! roster of fixed (padded) shapes and records them in
//! `artifacts/manifest.json`; the runtime picks the smallest variant that
//! fits a request and zero-pads inputs up to it.

use crate::util::json::Json;

/// Kind of compute graph an artifact implements.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// dist²(x_tile, centroids) → [tile, kp]
    KmeansAssign,
    /// exp(−γ·dist(x_tile, y_tile)) → [tile, tile]; Laplacian or Gaussian.
    KernelBlockLaplacian,
    KernelBlockGaussian,
    /// cos(x_tile·W + b) → [tile, r]
    RfFeatures,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<ArtifactKind, String> {
        match s {
            "kmeans_assign" => Ok(ArtifactKind::KmeansAssign),
            "kernel_block_laplacian" => Ok(ArtifactKind::KernelBlockLaplacian),
            "kernel_block_gaussian" => Ok(ArtifactKind::KernelBlockGaussian),
            "rf_features" => Ok(ArtifactKind::RfFeatures),
            other => Err(format!("unknown artifact kind '{other}'")),
        }
    }
}

/// One AOT-compiled artifact (an HLO text file plus its fixed shapes).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: ArtifactKind,
    pub file: String,
    /// Row-tile size T.
    pub tile: usize,
    /// Padded feature dimension Dp.
    pub dim: usize,
    /// Padded centroid count (kmeans_assign) — 0 otherwise.
    pub kp: usize,
    /// Padded RF feature count (rf_features) — 0 otherwise.
    pub r: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest, String> {
        let root = Json::parse(text)?;
        let entries = root
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or("manifest: missing 'entries' array")?;
        let mut out = Vec::with_capacity(entries.len());
        for (i, e) in entries.iter().enumerate() {
            let get_str = |k: &str| {
                e.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or(format!("manifest entry {i}: missing '{k}'"))
            };
            let get_num =
                |k: &str, default: usize| e.get(k).and_then(|v| v.as_usize()).unwrap_or(default);
            out.push(ArtifactEntry {
                name: get_str("name")?,
                kind: ArtifactKind::parse(&get_str("kind")?)?,
                file: get_str("file")?,
                tile: get_num("tile", 0),
                dim: get_num("dim", 0),
                kp: get_num("kp", 0),
                r: get_num("r", 0),
            });
        }
        Ok(Manifest { entries: out })
    }

    pub fn load(dir: &str) -> Result<Manifest, String> {
        let path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read manifest '{path}': {e}"))?;
        Self::parse(&text)
    }

    /// Smallest variant of `kind` whose padded shapes fit (d ≤ dim, and for
    /// kmeans k ≤ kp, for RF r_req ≤ r).
    pub fn select(&self, kind: ArtifactKind, d: usize, k: usize, r: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && e.dim >= d)
            .filter(|e| match kind {
                ArtifactKind::KmeansAssign => e.kp >= k,
                ArtifactKind::RfFeatures => e.r >= r,
                _ => true,
            })
            .min_by_key(|e| (e.dim, e.kp.max(e.r)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": 1,
        "entries": [
            {"name": "ka32", "kind": "kmeans_assign", "file": "ka32.hlo.txt", "tile": 2048, "dim": 32, "kp": 32},
            {"name": "ka128", "kind": "kmeans_assign", "file": "ka128.hlo.txt", "tile": 2048, "dim": 128, "kp": 32},
            {"name": "kb32", "kind": "kernel_block_laplacian", "file": "kb32.hlo.txt", "tile": 512, "dim": 32},
            {"name": "rf128", "kind": "rf_features", "file": "rf128.hlo.txt", "tile": 2048, "dim": 128, "r": 1024}
        ]
    }"#;

    #[test]
    fn parse_and_select() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.entries.len(), 4);
        // d=20 fits the 32-dim variant
        let e = m.select(ArtifactKind::KmeansAssign, 20, 10, 0).unwrap();
        assert_eq!(e.name, "ka32");
        // d=64 needs the 128-dim variant
        let e = m.select(ArtifactKind::KmeansAssign, 64, 10, 0).unwrap();
        assert_eq!(e.name, "ka128");
        // k too large for kp=32
        assert!(m.select(ArtifactKind::KmeansAssign, 20, 64, 0).is_none());
        // d too large entirely
        assert!(m.select(ArtifactKind::KmeansAssign, 1000, 10, 0).is_none());
        // rf respects r
        assert!(m.select(ArtifactKind::RfFeatures, 64, 0, 4096).is_none());
        assert!(m.select(ArtifactKind::RfFeatures, 64, 0, 512).is_some());
    }

    #[test]
    fn rejects_bad_kind() {
        let bad = r#"{"entries": [{"name":"x","kind":"nope","file":"f"}]}"#;
        assert!(Manifest::parse(bad).is_err());
    }
}
