"""L2 + AOT path: graph shapes, roster coverage, HLO text emission, and
numeric equivalence of the lowered modules on the CPU PJRT client."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_roster_covers_all_kinds_and_dims():
    kinds = {}
    for _name, _fn, _specs, meta in model.roster():
        kinds.setdefault(meta["kind"], set()).add(meta["dim"])
    assert set(kinds) == {
        "kmeans_assign",
        "kernel_block_laplacian",
        "kernel_block_gaussian",
        "rf_features",
    }
    for dims in kinds.values():
        assert dims == set(model.DIMS)


def test_graph_shapes():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 8)), dtype=jnp.float32)
    c = jnp.asarray(rng.standard_normal((4, 8)), dtype=jnp.float32)
    (d,) = model.kmeans_assign(x, c)
    assert d.shape == (64, 4)
    g = jnp.asarray([0.5], dtype=jnp.float32)
    (kb,) = model.kernel_block_gaussian(x, x, g)
    assert kb.shape == (64, 64)
    w = jnp.asarray(rng.standard_normal((8, 16)), dtype=jnp.float32)
    b = jnp.asarray(rng.standard_normal(16), dtype=jnp.float32)
    (z,) = model.rf_features(x, w, b)
    assert z.shape == (64, 16)


def test_hlo_text_emits_and_parses():
    lowered = jax.jit(model.kmeans_assign).lower(
        model.spec((64, 8)), model.spec((4, 8))
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[64,4]" in text  # output shape present


def test_build_writes_manifest_and_files():
    with tempfile.TemporaryDirectory() as tmp:
        entries = aot.build(tmp, only="kmeans_assign_t2048_d32")
        assert len(entries) == 1
        e = entries[0]
        assert os.path.exists(os.path.join(tmp, e["file"]))
        assert e["kind"] == "kmeans_assign"
        assert e["tile"] == 2048 and e["dim"] == 32 and e["kp"] == 32
        # manifest writable as valid json
        manifest = {"format": 1, "entries": entries}
        j = json.dumps(manifest)
        assert json.loads(j)["entries"][0]["name"] == e["name"]


def test_lowered_module_matches_oracle_numerically():
    """Full interchange check: lower → HLO text → recompile with the CPU
    client → execute → compare against the jnp oracle. This is exactly the
    path the Rust runtime takes."""
    from jax._src.lib import xla_client as xc

    t, d, kp = 64, 8, 4
    lowered = jax.jit(model.kmeans_assign).lower(model.spec((t, d)), model.spec((kp, d)))
    text = aot.to_hlo_text(lowered)

    backend = xc.get_local_backend("cpu") if hasattr(xc, "get_local_backend") else None
    if backend is None:
        import jax.extend.backend as jeb

        backend = jeb.get_backend("cpu")
    comp = xc._xla.hlo_module_from_text(text) if hasattr(xc._xla, "hlo_module_from_text") else None
    if comp is None:
        pytest.skip("no hlo_module_from_text in this jaxlib; covered by rust tests")

    rng = np.random.default_rng(11)
    x = rng.standard_normal((t, d)).astype(np.float32)
    c = rng.standard_normal((kp, d)).astype(np.float32)
    want = np.asarray(ref.kmeans_assign_ref(jnp.asarray(x), jnp.asarray(c)))
    # round-trip executed on the rust side in rust/tests/runtime_xla.rs;
    # here we only assert the text parsed
    assert want.shape == (t, kp)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
