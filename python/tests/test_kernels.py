"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes and value ranges. This is the CORE correctness
signal for the compute layer (the Rust side then revalidates the AOT'd
artifacts against the same oracles in rust/tests/runtime_xla.rs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    pallas_kernel_block,
    pallas_kmeans,
    pallas_rf,
    ref,
)

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


# ------------------------------------------------------------- kmeans

@settings(max_examples=25, deadline=None)
@given(
    t_blocks=st.integers(1, 4),
    bt=st.sampled_from([8, 32, 64]),
    d=st.integers(1, 40),
    kp=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmeans_assign_matches_ref(t_blocks, bt, d, kp, seed):
    rng = np.random.default_rng(seed)
    t = t_blocks * bt
    x = rand(rng, t, d)
    c = rand(rng, kp, d)
    got = pallas_kmeans.kmeans_assign(x, c, block_t=bt)
    want = ref.kmeans_assign_ref(x, c)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_kmeans_assign_zero_distance_on_centroids():
    rng = np.random.default_rng(0)
    c = rand(rng, 4, 8)
    x = jnp.tile(c, (2, 1))  # 8 rows = centroids twice
    d = pallas_kmeans.kmeans_assign(x, c, block_t=8)
    for i in range(8):
        assert abs(float(d[i, i % 4])) < 1e-4


# ------------------------------------------------------- kernel blocks

@settings(max_examples=20, deadline=None)
@given(
    blocks=st.integers(1, 3),
    b=st.sampled_from([8, 16]),
    d=st.integers(1, 24),
    gamma=st.floats(0.05, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_gaussian_block_matches_ref(blocks, b, d, gamma, seed):
    rng = np.random.default_rng(seed)
    t = blocks * b
    x = rand(rng, t, d)
    y = rand(rng, t, d)
    g = jnp.asarray([gamma], dtype=jnp.float32)
    got = pallas_kernel_block.kernel_block_gaussian(x, y, g, block=b)
    want = ref.kernel_block_gaussian_ref(x, y, g)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    blocks=st.integers(1, 3),
    b=st.sampled_from([8, 16]),
    d=st.sampled_from([1, 4, 17, 32, 128]),
    gamma=st.floats(0.05, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_laplacian_block_matches_ref(blocks, b, d, gamma, seed):
    rng = np.random.default_rng(seed)
    t = blocks * b
    x = rand(rng, t, d)
    y = rand(rng, t, d)
    g = jnp.asarray([gamma], dtype=jnp.float32)
    got = pallas_kernel_block.kernel_block_laplacian(x, y, g, block=b)
    want = ref.kernel_block_laplacian_ref(x, y, g)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_laplacian_chunked_path_d800():
    # exercises the fori_loop feature-chunk path (d > 128, chunk=100)
    rng = np.random.default_rng(3)
    x = rand(rng, 16, 800)
    y = rand(rng, 16, 800)
    g = jnp.asarray([0.3], dtype=jnp.float32)
    got = pallas_kernel_block.kernel_block_laplacian(x, y, g, block=16)
    want = ref.kernel_block_laplacian_ref(x, y, g)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-5)


def test_kernel_blocks_symmetry_and_unit_diag():
    rng = np.random.default_rng(5)
    x = rand(rng, 16, 6)
    g = jnp.asarray([1.0], dtype=jnp.float32)
    for fn in (
        pallas_kernel_block.kernel_block_gaussian,
        pallas_kernel_block.kernel_block_laplacian,
    ):
        k = np.asarray(fn(x, x, g, block=8))
        np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.diag(k), 1.0, rtol=1e-5)


# ------------------------------------------------------------------ rf

@settings(max_examples=20, deadline=None)
@given(
    t_blocks=st.integers(1, 3),
    bt=st.sampled_from([8, 32]),
    d=st.integers(1, 24),
    r=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rf_features_matches_ref(t_blocks, bt, d, r, seed):
    rng = np.random.default_rng(seed)
    t = t_blocks * bt
    x = rand(rng, t, d)
    w = rand(rng, d, r)
    b = rand(rng, r)
    got = pallas_rf.rf_features(x, w, b, block_t=bt)
    want = ref.rf_features_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_rf_features_bounded():
    rng = np.random.default_rng(7)
    z = pallas_rf.rf_features(rand(rng, 32, 5), rand(rng, 5, 16), rand(rng, 16), block_t=16)
    assert float(jnp.max(jnp.abs(z))) <= 1.0 + 1e-5


# -------------------------------------------------- VMEM budget guards

def test_vmem_budgets_under_16mb():
    assert pallas_kmeans.vmem_bytes(256, 800, 32) < 16 * 2**20
    assert pallas_kernel_block.vmem_bytes_laplacian(128, 100) < 16 * 2**20
    assert pallas_rf.vmem_bytes(256, 800, 1024) < 16 * 2**20


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
