"""AOT lowering: L2 graphs (wrapping L1 Pallas kernels) → HLO text +
manifest, consumed by the Rust runtime.

HLO **text** is the interchange format, not serialized HloModuleProto:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: str, only: str | None = None) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for name, fn, specs, meta in model.roster():
        if only and only not in name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entry = {"name": name, "file": fname, **meta}
        entries.append(entry)
        print(f"  wrote {fname} ({len(text)} chars)")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="substring filter on variant names")
    args = ap.parse_args()

    print(f"AOT-lowering {len(model.roster())} variants to {args.out}")
    entries = build(args.out, args.only)
    manifest = {"format": 1, "entries": entries}
    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote manifest.json ({len(entries)} entries)")


if __name__ == "__main__":
    main()
