"""L1 Pallas kernels: exact kernel blocks (Gaussian + Laplacian).

The O(N²d) similarity-graph path of exact SC and the Nyström/landmark
baselines, tiled as [bi, bj] output blocks over a 2-D grid.

TPU mapping (DESIGN.md §Hardware-Adaptation):
- Gaussian uses the matmul identity ‖x−y‖² = ‖x‖² + ‖y‖² − 2x·y, so the
  inner loop is a [bi, d] × [d, bj] MXU contraction (same shape as a
  flash-attention logits block).
- Laplacian needs Σ|x_l − y_l| which has no matmul form; the kernel walks
  the feature dimension in fixed chunks with a fori_loop so the broadcast
  intermediate [bi, bj, dc] stays VMEM-sized (bi=bj=128, dc=100 →
  ≈6.6 MB f32), instead of materializing [bi, bj, d].

interpret=True for CPU-PJRT portability (see pallas_kmeans.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 128
# feature-chunk size for the Laplacian accumulation loop
D_CHUNK = 100


def _gaussian_kernel(x_ref, y_ref, g_ref, o_ref):
    xb = x_ref[...]                                    # [bi, d]
    yb = y_ref[...]                                    # [bj, d]
    gamma = g_ref[0]
    x2 = jnp.sum(xb * xb, axis=1, keepdims=True)
    y2 = jnp.sum(yb * yb, axis=1)[None, :]
    cross = jax.lax.dot_general(
        xb, yb, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    d2 = jnp.maximum(x2 + y2 - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-gamma * d2)


def _laplacian_kernel_factory(d: int, d_chunk: int):
    n_chunks, rem = divmod(d, d_chunk)
    assert rem == 0, f"d={d} not divisible by chunk {d_chunk}"

    def kernel(x_ref, y_ref, g_ref, o_ref):
        gamma = g_ref[0]

        def body(ci, acc):
            lo = ci * d_chunk
            xs = pl.load(x_ref, (slice(None), pl.dslice(lo, d_chunk)))  # [bi, dc]
            ys = pl.load(y_ref, (slice(None), pl.dslice(lo, d_chunk)))  # [bj, dc]
            diff = jnp.abs(xs[:, None, :] - ys[None, :, :])             # [bi, bj, dc]
            return acc + jnp.sum(diff, axis=-1)

        bi = x_ref.shape[0]
        bj = y_ref.shape[0]
        acc = jnp.zeros((bi, bj), dtype=jnp.float32)
        acc = jax.lax.fori_loop(0, n_chunks, body, acc)
        o_ref[...] = jnp.exp(-gamma * acc)

    return kernel


def _block_call(kernel, x, y, gamma, block):
    t, d = x.shape
    t2, _ = y.shape
    bi = min(block, t)
    bj = min(block, t2)
    assert t % bi == 0 and t2 % bj == 0
    return pl.pallas_call(
        kernel,
        grid=(t // bi, t2 // bj),
        in_specs=[
            pl.BlockSpec((bi, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bj, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bi, bj), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, t2), jnp.float32),
        interpret=True,
    )(x, y, gamma)


def kernel_block_gaussian(x, y, gamma, block: int = DEFAULT_BLOCK):
    """exp(-gamma‖x_i−y_j‖²) for row tiles x [t,d], y [t,d]; gamma: [1]."""
    return _block_call(_gaussian_kernel, x, y, gamma, block)


def kernel_block_laplacian(x, y, gamma, block: int = DEFAULT_BLOCK):
    """exp(-gamma‖x_i−y_j‖₁); feature dim walked in VMEM-sized chunks."""
    d = x.shape[1]
    d_chunk = d if d <= 128 else D_CHUNK
    kernel = _laplacian_kernel_factory(d, d_chunk)
    return _block_call(kernel, x, y, gamma, block)


def vmem_bytes_laplacian(block: int, d_chunk: int) -> int:
    """Estimated VMEM working set per grid step (f32): the broadcast
    intermediate dominates."""
    return 4 * (block * block * d_chunk + 2 * block * d_chunk + block * block)
