"""L1 Pallas kernel: K-means assignment distances.

The NK²t hot spot of every method's final step. TPU mapping (DESIGN.md
§Hardware-Adaptation): the distance matrix is computed as
‖x‖² + ‖c‖² − 2·x@cᵀ so the inner loop is a [bt, d] × [d, kp] contraction
feeding the MXU; the (small) centroid block stays resident in VMEM across
the row-tile grid, and x streams HBM→VMEM one row block per grid step.

VMEM working set per step (f32): bt·d + kp·d + bt·kp
  = 256·800 + 32·800 + 256·32 ≈ 0.94 MB — comfortably under ~16 MB.

interpret=True: CPU PJRT cannot run Mosaic custom-calls; the lowered HLO
is portable and is what the Rust runtime loads.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-block size: multiple of 8 (f32 sublane) and large enough to keep the
# MXU busy on the [bt, d] x [d, kp] contraction.
DEFAULT_BLOCK_T = 256


def _assign_kernel(x_ref, c_ref, o_ref):
    xb = x_ref[...]                                   # [bt, d]
    cb = c_ref[...]                                   # [kp, d]
    x2 = jnp.sum(xb * xb, axis=1, keepdims=True)      # [bt, 1]
    c2 = jnp.sum(cb * cb, axis=1)[None, :]            # [1, kp]
    # MXU contraction: [bt, d] @ [d, kp]
    cross = jax.lax.dot_general(
        xb, cb, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # [bt, kp]
    o_ref[...] = x2 + c2 - 2.0 * cross


def kmeans_assign(x, c, block_t: int = DEFAULT_BLOCK_T):
    """Squared distances [t, kp] between rows of x [t, d] and c [kp, d]."""
    t, d = x.shape
    kp, d2 = c.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    bt = min(block_t, t)
    assert t % bt == 0, f"tile {t} not divisible by block {bt}"
    return pl.pallas_call(
        _assign_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),   # x streams by row block
            pl.BlockSpec((kp, d), lambda i: (0, 0)),   # centroids resident
        ],
        out_specs=pl.BlockSpec((bt, kp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, kp), jnp.float32),
        interpret=True,
    )(x, c)


def vmem_bytes(block_t: int, d: int, kp: int) -> int:
    """Estimated VMEM working set per grid step (f32)."""
    return 4 * (block_t * d + kp * d + block_t * kp)
