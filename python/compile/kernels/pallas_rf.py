"""L1 Pallas kernel: Random Fourier feature map cos(x·W + b).

The RF-baseline feature generation (SC_RF / SV_RF / KK_RF). A single
[bt, d] × [d, r] MXU contraction per row block with W resident in VMEM
(d=800, r=1024 → 3.3 MB f32), followed by an elementwise cos on the VPU.
The √(2/R) scale is applied by the Rust caller so padded columns can be
sliced off first.

interpret=True for CPU-PJRT portability (see pallas_kmeans.py).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 256


def _rf_kernel(x_ref, w_ref, b_ref, o_ref):
    xb = x_ref[...]                                   # [bt, d]
    wb = w_ref[...]                                   # [d, r]
    bb = b_ref[...]                                   # [r]
    proj = jax.lax.dot_general(
        xb, wb, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                  # [bt, r]
    o_ref[...] = jnp.cos(proj + bb[None, :])


def rf_features(x, w, b, block_t: int = DEFAULT_BLOCK_T):
    """cos(x@w + b): x [t, d], w [d, r], b [r] -> [t, r]."""
    t, d = x.shape
    d2, r = w.shape
    assert d == d2
    bt = min(block_t, t)
    assert t % bt == 0
    return pl.pallas_call(
        _rf_kernel,
        grid=(t // bt,),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((d, r), lambda i: (0, 0)),    # W resident
            pl.BlockSpec((r,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bt, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, r), jnp.float32),
        interpret=True,
    )(x, w, b)


def vmem_bytes(block_t: int, d: int, r: int) -> int:
    """Estimated VMEM working set per grid step (f32)."""
    return 4 * (block_t * d + d * r + r + block_t * r)
