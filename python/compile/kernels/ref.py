"""Pure-jnp oracles for the L1 Pallas kernels.

These are the correctness ground truth: pytest checks every Pallas kernel
against these on hypothesis-generated shapes, and the Rust runtime's
numerics are validated against the same definitions in
rust/tests/runtime_xla.rs.
"""

import jax.numpy as jnp


def kmeans_assign_ref(x, c):
    """Squared Euclidean distances point-to-centroid.

    x: [t, d] f32, c: [kp, d] f32 -> [t, kp] f32.
    """
    x2 = jnp.sum(x * x, axis=1, keepdims=True)      # [t, 1]
    c2 = jnp.sum(c * c, axis=1)[None, :]            # [1, kp]
    cross = x @ c.T                                  # [t, kp]
    return x2 + c2 - 2.0 * cross


def kernel_block_laplacian_ref(x, y, gamma):
    """exp(-gamma * ||x_i - y_j||_1); gamma = 1/sigma.

    x: [t, d], y: [t, d], gamma: [1] -> [t, t].
    """
    diff = jnp.abs(x[:, None, :] - y[None, :, :])   # [t, t, d]
    return jnp.exp(-gamma[0] * jnp.sum(diff, axis=-1))


def kernel_block_gaussian_ref(x, y, gamma):
    """exp(-gamma * ||x_i - y_j||^2); gamma = 1/(2 sigma^2)."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    y2 = jnp.sum(y * y, axis=1)[None, :]
    cross = x @ y.T
    d2 = jnp.maximum(x2 + y2 - 2.0 * cross, 0.0)
    return jnp.exp(-gamma[0] * d2)


def rf_features_ref(x, w, b):
    """cos(x @ w + b) — the sqrt(2/R) scale is applied by the caller.

    x: [t, d], w: [d, r], b: [r] -> [t, r].
    """
    return jnp.cos(x @ w + b[None, :])
