"""L2 — JAX compute graphs for the pipeline's dense hot spots.

Each graph is a thin jax function that calls the corresponding L1 Pallas
kernel, so a single AOT lowering captures both layers in one HLO module.
`aot.py` lowers each graph for a fixed roster of padded shapes; the Rust
runtime (rust/src/runtime/) pads inputs up to the nearest variant.

All graphs return 1-tuples: the xla-crate loader unwraps with to_tuple1
(see /opt/xla-example/README.md).
"""

import jax
import jax.numpy as jnp

from .kernels import pallas_kernel_block, pallas_kmeans, pallas_rf


def kmeans_assign(x, c):
    """Squared point-to-centroid distances: x [t,d], c [kp,d] -> ([t,kp],).

    The NK²t term of Algorithm 2's step 5 (and of the K-means baseline).
    """
    return (pallas_kmeans.kmeans_assign(x, c),)


def kernel_block_laplacian(x, y, gamma):
    """exp(-gamma·‖x_i−y_j‖₁): x [t,d], y [t,d], gamma [1] -> ([t,t],)."""
    return (pallas_kernel_block.kernel_block_laplacian(x, y, gamma),)


def kernel_block_gaussian(x, y, gamma):
    """exp(-gamma·‖x_i−y_j‖²): x [t,d], y [t,d], gamma [1] -> ([t,t],)."""
    return (pallas_kernel_block.kernel_block_gaussian(x, y, gamma),)


def rf_features(x, w, b):
    """cos(x·W + b): x [t,d], w [d,r], b [r] -> ([t,r],)."""
    return (pallas_rf.rf_features(x, w, b),)


def spec(shape):
    """f32 ShapeDtypeStruct shorthand."""
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# ---------------------------------------------------------------- roster

# Padded shape variants compiled by aot.py. Dp covers the Table 1 feature
# dimensions (16..54 → 64; 780 → 800); Kp=32 covers K ≤ 26 (letter).
KMEANS_TILE = 2048
KMEANS_KP = 32
KERNEL_TILE = 512
RF_TILE = 2048
RF_R = 1024
DIMS = (32, 128, 800)


def roster():
    """All (name, fn, arg specs, meta) variants to AOT-compile."""
    out = []
    for d in DIMS:
        out.append(
            (
                f"kmeans_assign_t{KMEANS_TILE}_d{d}_k{KMEANS_KP}",
                kmeans_assign,
                (spec((KMEANS_TILE, d)), spec((KMEANS_KP, d))),
                {"kind": "kmeans_assign", "tile": KMEANS_TILE, "dim": d, "kp": KMEANS_KP},
            )
        )
        out.append(
            (
                f"kernel_block_laplacian_t{KERNEL_TILE}_d{d}",
                kernel_block_laplacian,
                (spec((KERNEL_TILE, d)), spec((KERNEL_TILE, d)), spec((1,))),
                {"kind": "kernel_block_laplacian", "tile": KERNEL_TILE, "dim": d},
            )
        )
        out.append(
            (
                f"kernel_block_gaussian_t{KERNEL_TILE}_d{d}",
                kernel_block_gaussian,
                (spec((KERNEL_TILE, d)), spec((KERNEL_TILE, d)), spec((1,))),
                {"kind": "kernel_block_gaussian", "tile": KERNEL_TILE, "dim": d},
            )
        )
        out.append(
            (
                f"rf_features_t{RF_TILE}_d{d}_r{RF_R}",
                rf_features,
                (spec((RF_TILE, d)), spec((d, RF_R)), spec((RF_R,))),
                {"kind": "rf_features", "tile": RF_TILE, "dim": d, "r": RF_R},
            )
        )
    return out
