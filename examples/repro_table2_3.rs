//! Reproduce Tables 1–3: dataset properties, average rank scores over
//! (NMI, RI, FM, Acc), and wallclock for all 9 methods × 8 benchmarks.
//!
//!     cargo run --release --example repro_table2_3 -- [--scale 64] [--r 1024]
//!
//! Paper protocol (§5.1): R = 1024 for all methods, shared σ, same seeds;
//! exact SC reported "−" where infeasible. Default --scale 64 keeps the
//! full grid tractable; use --full and --r 1024 for paper-size runs.

use scrb::cli::Args;
use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};

fn main() {
    let args = Args::from_env().unwrap();
    let scale = if args.flag("full") { 1 } else { args.get_usize("scale", 64).unwrap() };
    let mut cfg = PipelineConfig::default();
    cfg.apply_args(&args).unwrap();
    if args.get("r").is_none() {
        cfg.r = 1024; // paper setting
    }
    cfg.verbose = true;

    println!("Table 1: dataset properties");
    println!("{}", report::render_table1(scale));

    let coord = Coordinator::new(cfg, scale);
    let names: Vec<String> = args.get_str_list("datasets", &experiment::TABLE_DATASETS);
    let grid = experiment::table2_3(&coord, &names).expect("table driver failed");

    println!("\nTable 2: average rank scores (lower = better), R={}", coord.base_cfg.r);
    println!("{}", report::render_table2(&grid));
    println!("Table 3: computational time (seconds)");
    println!("{}", report::render_table3(&grid));
    println!("{}", report::render_detail(&grid));

    let json = report::grid_to_json(&grid).to_string();
    if let Ok(path) = report::save("table2_3.json", &json) {
        eprintln!("[saved {path}]");
    }
}
