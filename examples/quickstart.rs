//! Quickstart: the paper's pipeline (Algorithm 2) on a small synthetic
//! dataset, end to end, with the XLA engine when artifacts are present.
//!
//!     cargo run --release --example quickstart
//!
//! This is the E2E driver required by the repro spec: it exercises all
//! three layers (Rust coordinator → AOT XLA artifacts → Pallas-lowered
//! HLO) on a real small workload and prints the paper's metrics.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig};
use scrb::data::synth;
use scrb::metrics::all_metrics;
use scrb::runtime::XlaRuntime;

fn main() {
    // 1. data: the classic non-convex case K-means cannot solve
    let ds = synth::two_moons(2_000, 0.06, 7);
    println!("dataset: two moons, n={} d={} k={}", ds.n(), ds.d(), ds.k);

    // 2. configuration (Algorithm 2 inputs: K, R, kernel σ)
    let mut cfg = PipelineConfig::default();
    cfg.k = 2;
    cfg.r = 256;
    cfg.kernel = Kernel::Laplacian { sigma: 0.15 };
    cfg.engine = Engine::Auto;

    // 3. optional XLA runtime (AOT Pallas kernels; falls back to native)
    let xla = XlaRuntime::load(&cfg.artifacts_dir).ok();
    println!(
        "engine: {}",
        if xla.is_some() { "xla (AOT artifacts loaded)" } else { "native (no artifacts)" }
    );
    let env = Env::with_xla(cfg, xla.as_ref());

    // 4. run SC_RB and the K-means baseline
    for kind in [MethodKind::ScRb, MethodKind::KMeans] {
        let out = kind.run(&env, &ds.x);
        let m = all_metrics(&out.labels, &ds.y);
        println!(
            "{:<8} acc={:.3} nmi={:.3} ri={:.3} fm={:.3}   [{}]",
            kind.name(),
            m.accuracy,
            m.nmi,
            m.rand_index,
            m.f_measure,
            out.timer.summary()
        );
        if let Some(kappa) = out.info.kappa {
            println!("         κ = {kappa:.1} non-empty bins/grid (Definition 1)");
        }
    }
    println!("\nSC_RB separates the moons; K-means cannot — the paper's motivating contrast.");
}
