//! Quickstart: the paper's pipeline (Algorithm 2) as **composable
//! stages** — fit a method, sweep a knob with artifact reuse, export the
//! embedding artifact standalone, and run the same fit out-of-core.
//!
//!     cargo run --release --example quickstart
//!
//! This is the E2E driver required by the repro spec: it exercises all
//! three layers (Rust coordinator → AOT XLA artifacts → Pallas-lowered
//! HLO) on a real small workload and prints the paper's metrics. See
//! `examples/serve.rs` for the fit-once/predict-many serving shape.

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig, Solver};
use scrb::data::synth;
use scrb::metrics::all_metrics;
use scrb::model::{FittedModel, ScRbModel};
use scrb::pipeline::ArtifactCache;
use scrb::serve::{ServeClient, ServeConfig, Server};
use scrb::runtime::XlaRuntime;
use scrb::shard::{ShardFormat, ShardPlanner};
use scrb::stream::{
    corrupt_libsvm_text, fit_streaming, fit_streaming_sharded, ChunkReader, IngestPolicy,
    LibsvmChunks, OnBadRecord, StreamOpts,
};
use std::fmt::Write as _;
use std::time::Instant;

fn main() {
    // 1. data: the classic non-convex case K-means cannot solve
    let ds = synth::two_moons(2_000, 0.06, 7);
    println!("dataset: two moons, n={} d={} k={}", ds.n(), ds.d(), ds.k);

    // 2. configuration (Algorithm 2 inputs: K, R, kernel σ)
    let cfg = PipelineConfig::builder()
        .k(2)
        .r(256)
        .kernel(Kernel::Laplacian { sigma: 0.15 })
        .engine(Engine::Auto)
        .build();

    // 3. optional XLA runtime (AOT Pallas kernels; falls back to native)
    let xla = XlaRuntime::load(&cfg.artifacts_dir).ok();
    println!(
        "engine: {}",
        if xla.is_some() { "xla (AOT artifacts loaded)" } else { "native (no artifacts)" }
    );
    let env = Env::with_xla(cfg.clone(), xla.as_ref());

    // 4. every method is a stage composition (Normalize → Featurize →
    // Embed → Cluster); `fit` drives it end to end through the model API
    for kind in [MethodKind::ScRb, MethodKind::KMeans] {
        let fitted = kind.fit(&env, &ds.x).expect("fit failed");
        let out = &fitted.output;
        let m = all_metrics(&out.labels, &ds.y);
        println!(
            "{:<8} acc={:.3} nmi={:.3} ri={:.3} fm={:.3}   [{}]",
            kind.name(),
            m.accuracy,
            m.nmi,
            m.rand_index,
            m.f_measure,
            out.timer.summary()
        );
        if let Some(kappa) = out.info.kappa {
            println!("         κ = {kappa:.1} non-empty bins/grid (Definition 1)");
        }
        // the fit also yields a serving model: out-of-sample points
        // reuse the learned embedding without re-running the solver
        let fresh = synth::two_moons(200, 0.06, 99);
        let labels = fitted.model.predict(&fresh.x).expect("predict failed");
        let acc = scrb::metrics::accuracy(&labels, &fresh.y);
        println!("         out-of-sample predict on 200 fresh points: acc={acc:.3}");
    }
    println!("\nSC_RB separates the moons; K-means cannot — the paper's motivating contrast.");

    // 5. the same fit with the compressive solver (`--solver
    // compressive`): instead of extracting Ritz pairs with Davidson or
    // Lanczos, Chebyshev-filter O(log n) random signals through the RB
    // gram operator and cluster a row sample of the filtered signals.
    // Three knobs trade accuracy for gram products: `cheb_order` (filter
    // sharpness — each order is one fused gram product over the signal
    // block), `cheb_signals` (embedding redundancy η), and `cheb_sample`
    // (rows K-means sees before labels interpolate back over the graph).
    // Prefer it over Lanczos when K is large or the spectrum is clustered
    // near λ_K: filtering costs O(p·η) matvecs no matter how slowly Ritz
    // pairs would converge. For small K with a clean spectral gap the
    // eigensolvers stay cheaper and give tighter singular triplets.
    let cfg_csc = cfg
        .rebuild(|b| b.solver(Solver::Compressive).cheb_order(30).cheb_signals(8))
        .expect("compressive config");
    let env_csc = Env::with_xla(cfg_csc.clone(), xla.as_ref());
    let fitted = MethodKind::ScRb.fit(&env_csc, &ds.x).expect("compressive fit failed");
    let m = all_metrics(&fitted.output.labels, &ds.y);
    println!(
        "compressive SC_RB (p=30, η=8): acc={:.3} nmi={:.3}   [{}]",
        m.accuracy,
        m.nmi,
        fitted.output.timer.summary()
    );

    // 6. a k-sweep with artifact reuse: stages emit fingerprinted,
    // cacheable artifacts, so with the embedding width pinned
    // (`embed_dim`) the expensive upstream stages — RB featurization and
    // the iterative SVD — run once and every further k only re-runs
    // K-means. The same cache serves σ/R/solver sweeps (a σ-sweep reuses
    // the normalized input; a solver sweep reuses featurization).
    let mut cache = ArtifactCache::new();
    let t0 = Instant::now();
    for k in [2usize, 3, 4] {
        let cfg_k = cfg.rebuild(|b| b.embed_dim(4).k(k)).expect("sweep point");
        let env_k = Env::with_xla(cfg_k.clone(), xla.as_ref());
        let fitted = MethodKind::ScRb
            .pipeline(&cfg_k)
            .fit_cached(&env_k, &ds.x, &mut cache)
            .expect("pipeline fit failed");
        // the embedding artifact is a first-class value: Σ, the embedding
        // rows, and SC_RB's serving projection, exportable standalone
        let emb = &fitted.embedding;
        println!(
            "k={k}: inertia={:.4}  (embedding {}×{}, σ₁={:.4})",
            fitted.result.output.info.inertia,
            emb.u.rows,
            emb.u.cols,
            emb.s[0]
        );
    }
    println!(
        "k-sweep over 3 points: {:.2}s, {} cache hits / {} misses \
         (featurize + embed computed once)",
        t0.elapsed().as_secs_f64(),
        cache.hits,
        cache.misses
    );

    // 7. the same fit, out-of-core: the featurize stage reads a chunked
    // stream (stats pass, then block-wise RB featurization) with resident
    // input memory bounded by chunk_rows × d; the embed → cluster →
    // assemble tail is the identical driver the in-memory fit runs, so a
    // streamed fit is byte-identical to the *file-based* in-memory flow
    // (`scrb fit --data`, which min-max normalizes by the training stats)
    // on the same data and seed.
    let mut text = String::new();
    for i in 0..ds.n() {
        write!(text, "{}", ds.y[i]).unwrap();
        for (j, &v) in ds.x.row(i).iter().enumerate() {
            if v != 0.0 {
                write!(text, " {}:{v}", j + 1).unwrap();
            }
        }
        text.push('\n');
    }
    let cfg = PipelineConfig::builder()
        .k(2)
        .r(256)
        .kernel(Kernel::Laplacian { sigma: 0.15 })
        .engine(Engine::Native)
        .build();
    let clean_bytes = text.into_bytes();
    let mut reader = LibsvmChunks::from_bytes(clean_bytes.clone(), 256);
    let streamed = fit_streaming(
        &Env::new(cfg.clone()),
        &mut reader,
        &StreamOpts { k: Some(2), ..StreamOpts::default() },
    )
    .expect("streaming fit failed");
    let m = all_metrics(&streamed.output.labels, &streamed.y);
    println!(
        "streamed SC_RB (chunk_rows=256): acc={:.3} nmi={:.3} — same Algorithm 2, same \
         driver, input never resident",
        m.accuracy, m.nmi
    );

    // 8. the same fit, sharded: split the input into K shards (byte
    // ranges of one file, or whole files of a multi-file dataset), run
    // the two featurization passes on K worker threads, and merge the
    // shard-local codebooks in canonical first-seen order. The merged
    // fit is **bit-identical** to the sequential streamed fit for any
    // shard count — the shard count is an execution detail, not part of
    // the fit identity. At the CLI: `scrb fit --stream --shards 4`.
    let shard_dir =
        std::env::temp_dir().join(format!("scrb_quickstart_shards_{}", std::process::id()));
    std::fs::create_dir_all(&shard_dir).expect("shard tmpdir");
    let data_path = shard_dir.join("moons.libsvm").to_str().unwrap().to_string();
    std::fs::write(&data_path, &clean_bytes).expect("write shard input");
    let plan = ShardPlanner::new(4, 256, ShardFormat::Libsvm)
        .plan(&[data_path])
        .expect("shard plan");
    let mut shard_readers = ShardPlanner::open(&plan).expect("open shards");
    let mut shard_refs: Vec<&mut (dyn ChunkReader + Send)> =
        shard_readers.iter_mut().map(|r| r.as_mut()).collect();
    let sharded = fit_streaming_sharded(
        &Env::new(cfg.clone()),
        &mut shard_refs,
        &StreamOpts { k: Some(2), ..StreamOpts::default() },
    )
    .expect("sharded fit failed");
    assert_eq!(
        sharded.model.to_bytes(),
        streamed.model.to_bytes(),
        "sharded == sequential, byte for byte"
    );
    println!("sharded SC_RB over 4 shards: model bytes identical to the sequential fit");
    let _ = std::fs::remove_dir_all(&shard_dir);

    // 9. the same fit, fault-tolerant: dirty inputs are the norm at the
    // scale streaming targets. Under `--on-bad-record quarantine` the fit
    // skips malformed/non-finite records deterministically in both passes
    // (exact counts, capped located samples) and equals a fit on the
    // clean subset byte for byte; transient reader errors retry with
    // bounded backoff; `--checkpoint DIR` + `--resume` survive a mid-fit
    // kill bit-identically; v2 model files carry a checksum footer. See
    // "Failure modes & recovery" in the crate docs and `tests/faults.rs`.
    let (dirty, replaced) = corrupt_libsvm_text(&clean_bytes, 42, 10);
    let mut dirty_reader = LibsvmChunks::from_bytes(dirty, 256);
    let policy =
        IngestPolicy { on_bad_record: OnBadRecord::Quarantine, ..IngestPolicy::default() };
    let quarantined = fit_streaming(
        &Env::new(cfg),
        &mut dirty_reader,
        &StreamOpts { k: Some(2), policy, ..StreamOpts::default() },
    )
    .expect("quarantined fit failed");
    assert_eq!(quarantined.quarantine.skipped(), replaced.len(), "counts are exact");
    println!(
        "quarantined fit over {} corrupted lines: {}",
        replaced.len(),
        quarantined.quarantine.summary()
    );

    // 10. clustering-as-a-service: persist the streamed model, serve it
    // over TCP (micro-batching, deadlines, load shedding), label points
    // through the wire, hot-swap to the quarantined re-fit without
    // dropping in-flight requests, and drain. In production the daemon
    // is `scrb serve --model m.scrb --addr …`; see
    // examples/serve_client.rs for the full tour (rollback, STATUS).
    let dir = std::env::temp_dir().join(format!("scrb_quickstart_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("tmpdir");
    let path_v1 = dir.join("moons_v1.scrb").to_str().unwrap().to_string();
    let path_v2 = dir.join("moons_v2.scrb").to_str().unwrap().to_string();
    streamed.model.save(&path_v1).expect("save streamed model");
    quarantined.model.save(&path_v2).expect("save quarantined model");
    let server = Server::bind(ServeConfig::default(), ScRbModel::load(&path_v1).expect("load"))
        .expect("bind");
    let handle = server.spawn().expect("spawn daemon");
    let mut client = ServeClient::connect(&handle.addr().to_string()).expect("connect");
    let (v, wire_labels) = client.predict(&ds.x.row_block(0, 8)).expect("predict over TCP");
    println!("served 8 points over TCP by model v{v}: {wire_labels:?}");
    let v2 = client.swap(&path_v2).expect("hot swap");
    println!("hot-swapped the daemon to model v{v2}; in-flight requests unaffected");
    client.drain().expect("drain");
    handle.join().expect("daemon exits cleanly");
    let _ = std::fs::remove_dir_all(&dir);
}
