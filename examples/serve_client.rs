//! Clustering-as-a-service, end to end: fit → serve → predict over TCP
//! → hot-swap to a re-fitted model → attempt (and survive) a bad swap →
//! drain.
//!
//!     cargo run --release --example serve_client
//!
//! The daemon here runs in-process on a loopback socket; in production
//! it is the `scrb serve --model m.scrb --addr 0.0.0.0:7878` process and
//! the client side is exactly the same [`ServeClient`] calls. See
//! `examples/serve.rs` for the in-process (no daemon) serving shape and
//! the crate docs' "Failure modes & recovery" for the full resilience
//! contract (load shedding, deadlines, worker restarts, rollback).

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, Kernel, PipelineConfig};
use scrb::data::synth;
use scrb::model::{FittedModel, ScRbModel};
use scrb::serve::{ErrorCode, ServeClient, ServeConfig, Server, ServeError};
use std::time::Instant;

fn fit_and_save(sigma: f64, seed: u64, path: &str) -> ScRbModel {
    let ds = synth::two_moons(2_000, 0.06, seed);
    let cfg = PipelineConfig::builder()
        .k(2)
        .r(128)
        .kernel(Kernel::Laplacian { sigma })
        .engine(Engine::Native)
        .seed(seed)
        .build();
    let fitted = MethodKind::ScRb.fit(&Env::new(cfg), &ds.x).expect("fit");
    fitted.model.save(path).expect("save model");
    ScRbModel::load(path).expect("reload model")
}

fn main() {
    let dir = std::env::temp_dir().join(format!("scrb_serve_client_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("tmpdir");
    let path_v1 = dir.join("moons_v1.scrb").to_str().unwrap().to_string();
    let path_v2 = dir.join("moons_v2.scrb").to_str().unwrap().to_string();

    // 1. fit and persist two model generations (checksummed v2 format)
    let t0 = Instant::now();
    let model_v1 = fit_and_save(0.15, 7, &path_v1);
    fit_and_save(0.18, 8, &path_v2);
    println!("fit + saved two model generations in {:.2}s", t0.elapsed().as_secs_f64());

    // 2. serve generation 1 — this is what `scrb serve` does
    let server = Server::bind(ServeConfig::default(), model_v1).expect("bind");
    let handle = server.spawn().expect("spawn");
    let addr = handle.addr().to_string();
    println!("daemon on {addr}");

    // 3. label points over the wire; the response names the model version
    let mut client = ServeClient::connect(&addr).expect("connect");
    let probe = synth::two_moons(16, 0.06, 9).x;
    let (version, labels) = client.predict(&probe).expect("predict");
    println!("v{version} labeled {} points: {labels:?}", labels.len());

    // 4. hot swap to generation 2: validated (checksummed load +
    // self-check predict) before being atomically published
    let new_version = client.swap(&path_v2).expect("swap");
    let (v, _) = client.predict(&probe).expect("predict after swap");
    assert_eq!(v, new_version);
    println!("hot-swapped to v{new_version}; in-flight requests were unaffected");

    // 5. a corrupt file is rejected with a typed error naming the path,
    // and the daemon keeps serving the current model (rollback)
    let bad = dir.join("corrupt.scrb").to_str().unwrap().to_string();
    let mut bytes = std::fs::read(&path_v2).expect("read model");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xff;
    std::fs::write(&bad, &bytes).expect("write corrupt");
    match client.swap(&bad) {
        Err(ServeError::Rejected { code: ErrorCode::BadModel, message }) => {
            println!("bad swap rejected as expected: {message}");
        }
        other => panic!("corrupt swap must be rejected, got {other:?}"),
    }
    let (v, _) = client.predict(&probe).expect("predict after rollback");
    assert_eq!(v, new_version, "rollback keeps the last good model");

    // 6. observability: queue depth, shed/timeout/restart counters,
    // drift statistics, and the swap audit trail in one document
    let status = client.status().expect("status");
    println!("status: {}", status.to_string());

    // 7. graceful drain: queued work finishes, then the daemon exits
    client.drain().expect("drain");
    handle.join().expect("clean exit");
    println!("drained; daemon exited cleanly");
    let _ = std::fs::remove_dir_all(&dir);
}
