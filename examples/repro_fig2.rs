//! Reproduce Fig. 2: clustering accuracy and runtime vs the number of
//! random features R on the mnist-like benchmark, for SC_RB vs the
//! RF-based methods, with the exact-SC reference line.
//!
//!     cargo run --release --example repro_fig2 -- [--scale 64] [--rs 16,64,...]
//!
//! Expected shape: SC_RB reaches the exact-SC accuracy at R ≈ 1024 while
//! SC_RF needs ≈ 4096 (Theorem 2's κ-fold faster convergence).

use scrb::cli::Args;
use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};

fn main() {
    let args = Args::from_env().unwrap();
    let scale = if args.flag("full") { 1 } else { args.get_usize("scale", 64).unwrap() };
    let mut cfg = PipelineConfig::default();
    cfg.apply_args(&args).unwrap();
    cfg.verbose = true;
    let coord = Coordinator::new(cfg, scale);

    let rs = args.get_usize_list("rs", &[16, 64, 256, 1024, 4096]).unwrap();
    let rb_max = args.get_usize("rb-max-r", 1024).unwrap();
    let fig = experiment::fig2(&coord, &rs, rb_max).expect("fig2 driver failed");
    println!("{}", report::render_fig2(&fig));

    // CSV for plotting
    let mut csv = String::from("method,r,acc,secs\n");
    for s in &fig.series {
        for p in &s.points {
            csv.push_str(&format!("{},{},{},{}\n", s.label, p.x as usize, p.acc, p.secs));
        }
    }
    if let Ok(path) = report::save("fig2.csv", &csv) {
        eprintln!("[saved {path}]");
    }
}
