//! Fit-once / predict-many: the serving shape of the model API.
//!
//!     cargo run --release --example serve
//!
//! 1. Fit SC_RB (Algorithm 2) on a training set.
//! 2. Persist the model (`.scrb`, versioned binary: RB grids, bin→column
//!    tables, Σ/V projection, K-means centroids).
//! 3. Reload it — as a serving process would — and label held-out points
//!    with `predict_batch`: R table lookups + R·K flops per point, no
//!    solver, no refit.

use scrb::cluster::ScRb;
use scrb::config::{Kernel, PipelineConfig};
use scrb::data::synth;
use scrb::metrics::accuracy;
use scrb::model::{FittedModel, ScRbModel, ServeWorkspace};
use scrb::util::rng::Pcg;
use std::time::Instant;

fn main() {
    // -- training and held-out data from the same two-moons distribution
    let mut ds = synth::two_moons(4_000, 0.06, 7);
    ds.shuffle(&mut Pcg::seed(1));
    let train_idx: Vec<usize> = (0..3_000).collect();
    let test_idx: Vec<usize> = (3_000..ds.n()).collect();
    let train_x = ds.x.select_rows(&train_idx);
    let test_x = ds.x.select_rows(&test_idx);
    let test_y: Vec<usize> = test_idx.iter().map(|&i| ds.y[i]).collect();

    // -- fit once
    let cfg = PipelineConfig::builder()
        .k(2)
        .r(256)
        .kernel(Kernel::Laplacian { sigma: 0.15 })
        .build();
    let t0 = Instant::now();
    let fitted = ScRb::new(cfg).fit(&train_x).expect("fit failed");
    println!(
        "fit on n={} in {:.2}s  (this cost is paid once)",
        train_x.rows,
        t0.elapsed().as_secs_f64()
    );

    // -- persist + reload, as a separate serving process would
    let path = std::env::temp_dir().join("serve_example.scrb");
    let path = path.to_str().unwrap();
    fitted.model.save(path).expect("save failed");
    let model = ScRbModel::load(path).expect("load failed");
    println!(
        "model: {} clusters, R={} grids, D={} bins, {} KB on disk",
        model.n_clusters(),
        model.codebook.r,
        model.codebook.dim,
        std::fs::metadata(path).map(|m| m.len() / 1024).unwrap_or(0)
    );

    // -- predict many: the serving hot loop reuses one workspace
    let mut ws = ServeWorkspace::new();
    let mut labels: Vec<usize> = Vec::new();
    model.predict_batch(&test_x, &mut ws, &mut labels).expect("predict failed"); // warm
    let rounds = 50;
    let t1 = Instant::now();
    for _ in 0..rounds {
        model.predict_batch(&test_x, &mut ws, &mut labels).expect("predict failed");
    }
    let secs = t1.elapsed().as_secs_f64();
    let pts = (rounds * test_x.rows) as f64;
    println!(
        "served {:.0} predictions in {:.2}s ({:.2e} points/s, {:.2} µs/point)",
        pts,
        secs,
        pts / secs,
        1e6 * secs / pts
    );
    println!("held-out accuracy: {:.3}", accuracy(&labels, &test_y));
}
