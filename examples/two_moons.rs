//! Domain example: non-convex geometries (moons + rings) across all nine
//! methods — the visual intuition behind the paper's intro, as a table.
//!
//!     cargo run --release --example two_moons

use scrb::cluster::{Env, MethodKind};
use scrb::config::{Kernel, PipelineConfig};
use scrb::data::synth;
use scrb::metrics::all_metrics;
use scrb::util::table::Table;

fn main() {
    let cases = [
        ("two_moons", synth::two_moons(1_500, 0.06, 7), 0.15),
        ("rings", synth::concentric_rings(1_500, 2, 2, 0.12, 9), 0.3),
        ("blobs", synth::gaussian_blobs(1_500, 2, 2, 8.0, 11), 0.5),
    ];
    for (name, ds, sigma) in cases {
        println!("== {name} (n={} k={}) ==", ds.n(), ds.k);
        let mut t = Table::new(vec!["Method", "Acc", "NMI", "Time(s)"]);
        for kind in MethodKind::ALL {
            let cfg = PipelineConfig::builder()
                .k(ds.k)
                .r(256)
                .kernel(Kernel::Laplacian { sigma })
                .kmeans_replicates(5)
                .build();
            let t0 = std::time::Instant::now();
            let out = kind.run(&Env::new(cfg), &ds.x).expect("clustering failed");
            let secs = t0.elapsed().as_secs_f64();
            let m = all_metrics(&out.labels, &ds.y);
            t.row(vec![
                kind.name().to_string(),
                format!("{:.3}", m.accuracy),
                format!("{:.3}", m.nmi),
                format!("{secs:.2}"),
            ]);
        }
        println!("{}", t.render());
    }
}
