//! Reproduce Fig. 4: linear scalability of SC_RB in the number of samples
//! N — per-stage runtimes (RB generation / eigensolver / K-means / total)
//! on poker-like and susy-like data at fixed R.
//!
//!     cargo run --release --example repro_fig4 -- [--ns 1000,4000,...] [--r 256]
//!
//! Expected shape: every stage scales ~linearly in N (per-point cost ratio
//! printed at the end ≈ 1).

use scrb::cli::Args;
use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};

fn main() {
    let args = Args::from_env().unwrap();
    let mut cfg = PipelineConfig::default();
    cfg.apply_args(&args).unwrap();
    cfg.verbose = true;
    let coord = Coordinator::new(cfg, 1);

    let r = args.get_usize("r", 256).unwrap();
    let default_ns: &[usize] = if args.flag("full") {
        &[10_000, 40_000, 160_000, 640_000, 1_025_010]
    } else {
        &[1_000, 4_000, 16_000, 64_000, 256_000]
    };
    let ns = args.get_usize_list("ns", default_ns).unwrap();

    for dataset in ["poker", "susy"] {
        let points = experiment::fig4(&coord, dataset, &ns, r).expect("fig4 driver failed");
        println!("{}", report::render_fig4(dataset, &points));
        let mut csv = String::from("n,rb_secs,svd_secs,embed_secs,kmeans_secs,total_secs,acc\n");
        for p in &points {
            csv.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                p.n, p.rb_secs, p.svd_secs, p.embed_secs, p.kmeans_secs, p.total_secs, p.accuracy
            ));
        }
        let _ = report::save(&format!("fig4_{dataset}.csv"), &csv);
    }
}
