//! Empirical check of Theorems 1–2: the spectral objective gap
//! f(Û_R) − f(U*) under the exact normalized Laplacian shrinks like
//! O(1/(κR)) as the number of RB grids R grows.
//!
//!     cargo run --release --example convergence_theory [--n 300]

use scrb::cli::Args;
use scrb::config::{Engine, PipelineConfig};
use scrb::coordinator::{experiment, report, Coordinator};

fn main() {
    let args = Args::from_env().unwrap();
    let n = args.get_usize("n", 300).unwrap();
    let rs = args.get_usize_list("rs", &[4, 8, 16, 32, 64, 128, 256]).unwrap();

    let cfg = PipelineConfig::builder().engine(Engine::Native).build();
    let coord = Coordinator::new(cfg, 1);
    let points = experiment::theory_convergence(&coord, n, &rs).expect("theory driver failed");
    println!("{}", report::render_theory(&points));

    // quantify the fit: gap·κ·R should stay bounded while R spans ~2 decades
    let ratios: Vec<f64> = points.iter().map(|p| p.gap / p.predicted_slope).collect();
    println!("gap / (1/(κR)) per R (≈ constant ⇒ O(1/(κR)) as in Theorem 2):");
    for (p, ratio) in points.iter().zip(&ratios) {
        println!("  R={:<5} κ={:<7.2} gap·κ·R = {:.3}", p.r, p.kappa, ratio);
    }
}
