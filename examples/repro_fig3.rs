//! Reproduce Fig. 3: effect of the SVD solver on SC_RB for the
//! covtype-like benchmark — tiny eigengaps make it the stress case.
//! PRIMME_SVDS ↔ our Davidson GD+k; Matlab SVDS ↔ our restarted Lanczos.
//!
//!     cargo run --release --example repro_fig3 -- [--scale 64] [--rs 16,32,64,128]
//!
//! Expected shape: davidson's runtime grows slowly with R and accuracy is
//! consistent; lanczos is slower / less consistent on the clustered
//! spectrum (its naive restart discards subspace information).

use scrb::cli::Args;
use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};

fn main() {
    let args = Args::from_env().unwrap();
    let scale = if args.flag("full") { 1 } else { args.get_usize("scale", 64).unwrap() };
    let mut cfg = PipelineConfig::default();
    cfg.apply_args(&args).unwrap();
    cfg.verbose = true;
    let coord = Coordinator::new(cfg, scale);

    let rs = args.get_usize_list("rs", &[16, 32, 64, 128]).unwrap();
    let series = experiment::fig3(&coord, &rs).expect("fig3 driver failed");
    println!(
        "{}",
        report::render_series(
            "Fig. 3: SC_RB accuracy & runtime under different SVD solvers (covtype-like)",
            &series,
            "R"
        )
    );

    let mut csv = String::from("solver,r,acc,secs\n");
    for s in &series {
        for p in &s.points {
            csv.push_str(&format!("{},{},{},{}\n", s.label, p.x as usize, p.acc, p.secs));
        }
    }
    if let Ok(path) = report::save("fig3.csv", &csv) {
        eprintln!("[saved {path}]");
    }
}
