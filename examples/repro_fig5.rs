//! Reproduce Fig. 5: runtime scalability of all methods in the number of
//! latent features R, on the four panel datasets (pendigits, letter,
//! mnist, acoustic).
//!
//!     cargo run --release --example repro_fig5 -- [--scale 64] [--rs 16,64,256,1024]
//!
//! Expected shape: every approximation method is ~linear in R; KK_RF's
//! K-means-on-dense-Z cost blows up at large R; exact SC is the flat
//! quadratic reference where feasible.

use scrb::cli::Args;
use scrb::config::PipelineConfig;
use scrb::coordinator::{experiment, report, Coordinator};

fn main() {
    let args = Args::from_env().unwrap();
    let scale = if args.flag("full") { 1 } else { args.get_usize("scale", 64).unwrap() };
    let mut cfg = PipelineConfig::default();
    cfg.apply_args(&args).unwrap();
    cfg.verbose = true;
    let coord = Coordinator::new(cfg, scale);

    let rs = args.get_usize_list("rs", &[16, 64, 256, 1024]).unwrap();
    let names = args.get_str_list("datasets", &["pendigits", "letter", "mnist", "acoustic"]);
    let mut csv = String::from("dataset,method,r,acc,secs\n");
    for name in names {
        let series = experiment::fig5(&coord, &name, &rs).expect("fig5 driver failed");
        println!(
            "{}",
            report::render_series(&format!("Fig. 5: runtime vs R ({name})"), &series, "R")
        );
        for s in &series {
            for p in &s.points {
                csv.push_str(&format!(
                    "{},{},{},{},{}\n",
                    name, s.label, p.x as usize, p.acc, p.secs
                ));
            }
        }
    }
    if let Ok(path) = report::save("fig5.csv", &csv) {
        eprintln!("[saved {path}]");
    }
}
