//! Domain example: the large-scale regime the paper targets — SC_RB on a
//! few hundred thousand points where exact SC is simply impossible, with
//! the per-stage breakdown showing every component staying linear.
//!
//!     cargo run --release --example large_scale [--n 200000] [--r 256]

use scrb::cli::Args;
use scrb::cluster::{Env, MethodKind};
use scrb::config::{Engine, PipelineConfig};
use scrb::data::synth;
use scrb::kernels::median_heuristic_sigma;
use scrb::metrics::all_metrics;

fn main() {
    let args = Args::from_env().unwrap();
    let n = args.get_usize("n", 200_000).unwrap();
    let r = args.get_usize("r", 256).unwrap();

    let spec = synth::spec_by_name("poker").unwrap();
    let scale = (spec.n / n).max(1);
    let mut ds = synth::paper_benchmark("poker", scale, 42);
    ds.truncate(n);
    println!("dataset: poker-like n={} d={} k={}", ds.n(), ds.d(), ds.k);

    let sigma = median_heuristic_sigma("laplacian", &ds.x, 1);
    let cfg = PipelineConfig::builder()
        .k(ds.k)
        .r(r)
        .engine(Engine::Auto)
        .sigma(sigma)
        .build();
    println!("config: {cfg}");

    let xla = scrb::runtime::XlaRuntime::load(&cfg.artifacts_dir).ok();
    let env = Env::with_xla(cfg, xla.as_ref());
    let t0 = std::time::Instant::now();
    let out = MethodKind::ScRb.run(&env, &ds.x).expect("SC_RB failed");
    let total = t0.elapsed().as_secs_f64();
    let m = all_metrics(&out.labels, &ds.y);
    println!("SC_RB: acc={:.3} nmi={:.3}", m.accuracy, m.nmi);
    println!("stage breakdown: {}", out.timer.summary());
    println!("feature dim D={} (κ={:.1})", out.info.feature_dim, out.info.kappa.unwrap_or(0.0));
    println!(
        "throughput: {:.0} points/s end-to-end (exact SC at this N would need ~{:.1e} kernel evals)",
        ds.n() as f64 / total,
        (ds.n() as f64) * (ds.n() as f64) / 2.0
    );
}
